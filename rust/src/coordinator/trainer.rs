//! The training loop (Algorithm 3 end-to-end): data pipeline → model step
//! artifact → second-order preconditioning (parallel block engine, with
//! batch or staggered inverse-root scheduling, and optional cross-step
//! pipelining of PU/PIRU against subsequent model steps) → native
//! first-order update (chunked across the same persistent pool),
//! with per-stage wall-time accounting, eval, metrics, checkpointing
//! (params + codec-encoded first- AND second-order optimizer state + step —
//! raw codec bytes round-trip bit-exactly, so a resumed run continues the
//! exact trajectory of an uninterrupted one for every optimizer family),
//! exact memory accounting, and the optional 32-bit shadow for dynamic
//! quantization-error tracking (Figures 7/8).

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::{RunConfig, SecondOrderKind};
use crate::coordinator::checkpoint::{
    self, CheckpointError, CheckpointFile, CheckpointMeta, FrameSpec,
};
use crate::coordinator::model::{DataSource, ModelHandle};
use crate::coordinator::scheduler::{Scheduler, StepTimings};
use crate::coordinator::second_order::SecondOrder;
use crate::coordinator::shadow::ShadowTracker;
use crate::coordinator::state::SideState;
use crate::errors;
use crate::optim::{build_first_order, FirstOrder, StateSnapshot};
use crate::quant::EncodedVec;
use crate::runtime::Backend;
use crate::util::json::Json;
use crate::util::timer::Stopwatch;

/// One held-out evaluation.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    /// Trainer step at which the eval ran.
    pub step: usize,
    /// Mean held-out loss.
    pub loss: f32,
    /// classification accuracy in [0,1] when the model reports it
    pub accuracy: Option<f64>,
}

/// Exact live-state byte accounting (the Table 2/13 columns).
#[derive(Debug, Clone, Default)]
pub struct MemoryReport {
    /// Model parameter bytes.
    pub params_bytes: usize,
    /// Gradient buffer bytes.
    pub grads_bytes: usize,
    /// First-order optimizer state bytes (codec-exact).
    pub first_order_bytes: usize,
    /// Second-order optimizer state bytes (codec-exact).
    pub second_order_bytes: usize,
}

impl MemoryReport {
    /// Total bytes across all four classes.
    pub fn total(&self) -> usize {
        self.params_bytes + self.grads_bytes + self.first_order_bytes + self.second_order_bytes
    }

    /// Total in MiB.
    pub fn total_mb(&self) -> f64 {
        self.total() as f64 / (1024.0 * 1024.0)
    }

    /// Optimizer-state (first + second order) MiB.
    pub fn optimizer_mb(&self) -> f64 {
        (self.first_order_bytes + self.second_order_bytes) as f64 / (1024.0 * 1024.0)
    }
}

/// Everything a finished `train` call reports.
#[derive(Debug, Clone)]
pub struct TrainResult {
    /// The run's configured name.
    pub name: String,
    /// (step, training loss) samples every `log_every`.
    pub losses: Vec<(usize, f32)>,
    /// Periodic held-out evaluations.
    pub evals: Vec<EvalPoint>,
    /// The final held-out evaluation (when `eval_batches > 0`).
    pub final_eval: Option<EvalPoint>,
    /// Wall seconds for the whole call.
    pub wall_secs: f64,
    /// Live-state byte accounting.
    pub memory: MemoryReport,
    /// Dynamic quant-error rows (shadow mode only).
    pub shadow_rows: Vec<crate::coordinator::shadow::ShadowRow>,
    /// Preconditions served by the host mirror instead of an artifact.
    pub host_fallbacks: u64,
    /// per-stage wall time + worst-step spike (parallel block engine telemetry)
    pub timings: StepTimings,
}

impl TrainResult {
    /// Final held-out accuracy in percent, when measured.
    pub fn final_accuracy_pct(&self) -> Option<f64> {
        self.final_eval.as_ref().and_then(|e| e.accuracy).map(|a| a * 100.0)
    }

    /// Final held-out loss, when measured.
    pub fn final_loss(&self) -> Option<f32> {
        self.final_eval.as_ref().map(|e| e.loss)
    }
}

/// One training run: model, optimizers, data, and the engine that drives
/// them (see the module docs for the step anatomy).
pub struct Trainer {
    /// The run's full configuration.
    pub cfg: RunConfig,
    /// Model parameters + step/eval marshaling.
    pub model: ModelHandle,
    /// The native first-order optimizer F.
    pub first: Box<dyn FirstOrder>,
    /// The second-order preconditioner orchestration, when configured.
    pub second: Option<SecondOrder>,
    /// The run's data pipeline.
    pub data: DataSource,
    shadow: Option<ShadowTracker>,
    flat_len: usize,
    /// engine handle shared with `second` (same persistent pool): chunks the
    /// flat first-order update across the pool workers
    sched: Scheduler,
    /// last completed step of a loaded checkpoint; `train` resumes after it
    resume_step: usize,
}

impl Trainer {
    /// Build a trainer: model init, optimizers, data, and the engine.
    pub fn new(rt: &dyn Backend, cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        let model = ModelHandle::new(rt, &cfg.model, cfg.seed)?;
        let flat_len = model.param_count();
        let warmup = match cfg.schedule {
            crate::config::Schedule::Cosine { warmup } => warmup,
            crate::config::Schedule::MultiStep { warmup, .. } => warmup,
            _ => 10,
        };
        // the per-buffer codec policy resolves every state buffer's storage
        // codec (first-order moments AND second-order sides); roles without
        // an entry fall back to the legacy single knobs
        let policy = cfg.codec_policy();
        let first = build_first_order(&cfg.first, &policy, flat_len, warmup);
        let second = if cfg.second.kind == SecondOrderKind::None {
            None
        } else {
            Some(SecondOrder::new(
                &cfg.second,
                &policy,
                &model,
                &rt.manifest().buckets,
                &cfg.backend,
                Path::new(&cfg.artifact_dir),
            )?)
        };
        let shadow = if cfg.shadow_quant_error {
            second.as_ref().and_then(|s| ShadowTracker::new(s, &cfg.second))
        } else {
            None
        };
        let data = model.data_source(cfg.seed);
        // share the second-order engine's persistent pool; first-order-only
        // runs get their own (poolless at parallelism = 1)
        let sched = second
            .as_ref()
            .map(|s| s.scheduler().clone())
            .unwrap_or_else(|| Scheduler::new(cfg.second.parallelism));
        Ok(Self { cfg, model, first, second, data, shadow, flat_len, sched, resume_step: 0 })
    }

    fn flatten(bufs: &[Vec<f32>]) -> Vec<f32> {
        let mut out = Vec::with_capacity(bufs.iter().map(|b| b.len()).sum());
        for b in bufs {
            out.extend_from_slice(b);
        }
        out
    }

    fn scatter(flat: &[f32], bufs: &mut [Vec<f32>]) {
        let mut off = 0;
        for b in bufs.iter_mut() {
            let len = b.len();
            b.copy_from_slice(&flat[off..off + len]);
            off += len;
        }
    }

    /// Exact live-state byte accounting at this moment.
    pub fn memory_report(&self) -> MemoryReport {
        MemoryReport {
            params_bytes: self.model.params_bytes(),
            grads_bytes: self.model.params_bytes(),
            first_order_bytes: self.first.state_bytes(),
            second_order_bytes: self.second.as_ref().map(|s| s.state_bytes()).unwrap_or(0),
        }
    }

    /// Evaluate on `batches` held-out batches with the optimizer's eval
    /// parameters (schedule-free averages where applicable).
    pub fn evaluate(&self, rt: &dyn Backend, step: usize, batches: usize) -> Result<EvalPoint> {
        let flat = Self::flatten(&self.model.params);
        let eval_flat = self.first.eval_params(&flat);
        let mut eval_params = self.model.params.clone();
        Self::scatter(&eval_flat, &mut eval_params);
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut has_acc = false;
        for i in 0..batches {
            let batch = self.model.make_batch(&self.data, true, i as u64);
            let (loss, corr) = self.model.eval(rt, &eval_params, &batch)?;
            loss_sum += loss as f64;
            if let Some(c) = corr {
                has_acc = true;
                correct += c;
                total += self.model.spec.batch;
            }
        }
        Ok(EvalPoint {
            step,
            loss: (loss_sum / batches.max(1) as f64) as f32,
            accuracy: has_acc.then(|| correct as f64 / total.max(1) as f64),
        })
    }

    /// Run the configured number of steps. `metrics_path`: optional CSV.
    ///
    /// This wrapper exists for the pipelined engine's safety contract: any
    /// asynchronous PU/PIRU refresh still in flight when the loop exits —
    /// normally none, since the loop barriers at the end, but an error or
    /// panic can leave one — is aborted and drained *before* this function
    /// returns, so no background job outlives the borrowed backend and no
    /// pool thread is left wedged on abandoned work.
    pub fn train(&mut self, rt: &dyn Backend, metrics_path: Option<&Path>) -> Result<TrainResult> {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.train_inner(rt, metrics_path)
        }));
        if let Some(second) = self.second.as_mut() {
            second.abort_inflight();
        }
        match res {
            Ok(r) => r,
            Err(payload) => std::panic::resume_unwind(payload),
        }
    }

    fn train_inner(
        &mut self,
        rt: &dyn Backend,
        metrics_path: Option<&Path>,
    ) -> Result<TrainResult> {
        let mut csv = match metrics_path {
            Some(p) => {
                if let Some(dir) = p.parent() {
                    std::fs::create_dir_all(dir)?;
                }
                let mut w = std::fs::File::create(p)
                    .with_context(|| format!("creating {}", p.display()))?;
                use std::io::Write;
                writeln!(w, "step,loss,lr,eval_loss,eval_acc,elapsed_s")?;
                Some(w)
            }
            None => None,
        };
        let t0 = Stopwatch::start();
        let mut losses = Vec::new();
        let mut evals = Vec::new();
        let mut shadow_rows = Vec::new();
        let mut timings = StepTimings::default();
        let s2cfg = self.cfg.second.clone();
        let start = self.resume_step + 1;

        for step in start..=self.cfg.steps {
            let step_t = Stopwatch::start();
            let batch = self.model.make_batch(&self.data, false, step as u64);
            let t = Stopwatch::start();
            let (loss, mut grads, stats) = self.model.step(rt, &batch)?;
            timings.model_step_secs += t.secs();

            if let Some(second) = self.second.as_mut() {
                if step >= s2cfg.start_step {
                    let pu_due = step % s2cfg.update_precond_every == 0;
                    // batch mode: every block at T2 boundaries; staggered
                    // mode: one round-robin cohort per step
                    let due = second.invroot_due(step);
                    if second.pipelined() {
                        // deterministic completion barrier: a new refresh is
                        // due (the EMA chain needs the previous result), or
                        // the in-flight one hit the staleness bound
                        if pu_due || !due.is_empty() || second.inflight_lag_reached(step) {
                            second.complete_pipeline(&mut timings)?;
                        } else if s2cfg.pipeline_adaptive
                            && second.try_complete_pipeline(&mut timings)?
                        {
                            // adaptive lag: the pool went idle, so the
                            // finished refresh swaps in at this step's
                            // barrier instead of waiting out the lag bound
                            timings.pipeline_early_completes += 1;
                        }
                        if pu_due || !due.is_empty() {
                            second.submit_refresh(
                                rt,
                                &self.model,
                                &grads,
                                &stats,
                                pu_due,
                                &due,
                                step,
                            )?;
                            timings.pipeline_refreshes += 1;
                        }
                    } else {
                        if pu_due {
                            let t = Stopwatch::start();
                            second.update_preconditioners(rt, &self.model, &grads, &stats)?;
                            timings.pu_secs += t.secs();
                            if let Some(sh) = self.shadow.as_mut() {
                                sh.update_shadow(rt, second, &self.model, &grads, &stats)?;
                            }
                        }
                        if !due.is_empty() {
                            let t = Stopwatch::start();
                            second.update_invroots_subset(rt, &due)?;
                            timings.piru_secs += t.secs();
                            if let Some(sh) = self.shadow.as_mut() {
                                if due.contains(&sh.block_idx) {
                                    if let Some(row) = sh.measure(step, second)? {
                                        shadow_rows.push(row);
                                    }
                                }
                            }
                        }
                    }
                    let t = Stopwatch::start();
                    second.precondition(rt, &self.model, &mut grads)?;
                    timings.precond_secs += t.secs();
                }
            }

            // native first-order update over the flat parameter vector,
            // chunked across the persistent pool (bit-identical at any
            // worker count — the update is elementwise)
            let t = Stopwatch::start();
            let mut flat_p = Self::flatten(&self.model.params);
            let flat_g = Self::flatten(&grads);
            debug_assert_eq!(flat_p.len(), self.flat_len);
            let lr = self.cfg.first.lr * self.cfg.lr_at(step - 1);
            self.first.step_par(&mut flat_p, &flat_g, lr, &self.sched);
            Self::scatter(&flat_p, &mut self.model.params);
            timings.first_order_secs += t.secs();
            timings.note_step(step, step_t.secs());

            if step % self.cfg.log_every == 0 || step == 1 {
                losses.push((step, loss));
            }
            let do_eval = self.cfg.eval_every > 0 && step % self.cfg.eval_every == 0;
            let ev = if do_eval {
                let e = self.evaluate(rt, step, self.cfg.eval_batches)?;
                evals.push(e.clone());
                Some(e)
            } else {
                None
            };
            if let Some(w) = csv.as_mut() {
                use std::io::Write;
                writeln!(
                    w,
                    "{step},{loss},{lr},{},{},{:.3}",
                    ev.as_ref().map(|e| e.loss.to_string()).unwrap_or_default(),
                    ev.as_ref()
                        .and_then(|e| e.accuracy)
                        .map(|a| format!("{a:.4}"))
                        .unwrap_or_default(),
                    t0.secs()
                )?;
            }
        }

        // drain the pipeline so the final state (eval, checkpoints, a
        // subsequent `train` call) reflects every submitted refresh
        if let Some(second) = self.second.as_mut() {
            second.complete_pipeline(&mut timings)?;
            if let Some((wire, state, state_fp32, rounds)) = second.shard_wire_stats() {
                timings.shard_wire_bytes = wire;
                timings.shard_state_bytes = state;
                timings.shard_state_fp32_bytes = state_fp32;
                timings.shard_rounds = rounds;
            }
        }

        let final_eval = if self.cfg.eval_batches > 0 {
            Some(self.evaluate(rt, self.cfg.steps, self.cfg.eval_batches.max(8))?)
        } else {
            None
        };
        Ok(TrainResult {
            name: self.cfg.name.clone(),
            losses,
            evals,
            final_eval,
            wall_secs: t0.secs(),
            memory: self.memory_report(),
            shadow_rows,
            host_fallbacks: self.second.as_ref().map(|s| s.host_fallbacks).unwrap_or(0),
            timings,
        })
    }

    /// Run identity for a checkpoint header at `step`.
    fn checkpoint_meta(&self, step: usize, counters: Vec<f64>) -> CheckpointMeta {
        CheckpointMeta {
            model: self.model.name.clone(),
            step,
            param_count: self.model.param_count(),
            opt: self.first.name().to_string(),
            opt_counters: counters,
            // observability: the configured role→codec policy ("" when the
            // run used the single knobs). Enforcement is per buffer — every
            // manifest codec name must match on load, so a mismatched
            // policy is rejected even without this field.
            quant_policy: self.cfg.codec_policy().summary(),
            // observability only: restore recomputes the round-robin
            // assignment from the run's own shard count, so checkpoints
            // are shard-count-portable by construction
            shards: self.cfg.second.shards,
        }
    }

    /// One [`FrameSpec`] per state buffer, in manifest order: `param.{i}`
    /// (fp32 LE, emitted in `checkpoint_chunk_bytes` chunks), `opt.{i}`
    /// (raw first-order codec bytes), `so.{b}.left` / `so.{b}.right`
    /// (opaque side-state serializations, one side at a time) — the
    /// streaming seam: no whole-state blob is ever staged.
    fn checkpoint_frames<'a>(&'a self, snap: &'a StateSnapshot) -> Vec<FrameSpec<'a>> {
        let chunk_elems = (self.cfg.checkpoint_chunk_bytes / 4).max(1);
        let mut frames = Vec::new();
        for (i, p) in self.model.params.iter().enumerate() {
            frames.push(FrameSpec {
                role: format!("param.{i}"),
                codec: "fp32".to_string(),
                len: p.len(),
                emit: Box::new(move |sink: &mut dyn FnMut(&[u8])| {
                    for chunk in p.chunks(chunk_elems) {
                        let bytes: Vec<u8> =
                            chunk.iter().flat_map(|x| x.to_le_bytes()).collect();
                        sink(&bytes);
                    }
                }),
            });
        }
        for (i, (codec, e)) in snap.buffers.iter().enumerate() {
            frames.push(FrameSpec {
                role: format!("opt.{i}"),
                codec: codec.clone(),
                len: e.len,
                emit: Box::new(move |sink: &mut dyn FnMut(&[u8])| sink(&e.bytes)),
            });
        }
        if let Some(second) = self.second.as_ref() {
            for (bi, bp) in second.blocks.iter().enumerate() {
                for (side, tag) in [(&bp.left, "left"), (&bp.right, "right")] {
                    frames.push(FrameSpec {
                        role: format!("so.{bi}.{tag}"),
                        codec: checkpoint::SIDE_STATE_CODEC.to_string(),
                        len: 0,
                        emit: Box::new(move |sink: &mut dyn FnMut(&[u8])| {
                            let mut buf = Vec::new();
                            side.serialize_into(&mut buf);
                            sink(&buf);
                        }),
                    });
                }
            }
        }
        frames
    }

    /// Save parameters + full optimizer state + step metadata in the
    /// streaming v1 format (see [`checkpoint`]): a checksummed JSON header
    /// with a per-buffer manifest, then one frame per buffer — params as
    /// f32 LE, first-order buffers and second-order sides as raw codec
    /// bytes, persisted verbatim with no requantization, so loading
    /// restores the exact optimization trajectory for both optimizer
    /// families at any state bitwidth. The write is chunked (no full-state
    /// staging buffer) and crash-atomic: `<path>.tmp` + fsync + rename.
    /// (Stochastic-rounding buffers are the one caveat: the restore itself
    /// is byte-exact, but post-resume encodes draw a fresh rounding
    /// stream — see [`load_checkpoint`](Trainer::load_checkpoint).)
    pub fn save_checkpoint(&self, path: &Path, step: usize) -> Result<()> {
        let snap = self.first.export_state();
        let meta = self.checkpoint_meta(step, snap.counters.clone());
        let frames = self.checkpoint_frames(&snap);
        checkpoint::save(path, &meta, &frames)
    }

    /// Like [`save_checkpoint`](Trainer::save_checkpoint), but incremental
    /// against `parent` (an earlier v1 checkpoint): buffers whose codec
    /// bytes are unchanged are recorded in the manifest but not rewritten —
    /// readers resolve them through the parent chain. Restores from a delta
    /// chain are bit-identical to restores from a monolithic save.
    pub fn save_checkpoint_delta(&self, path: &Path, step: usize, parent: &Path) -> Result<()> {
        let snap = self.first.export_state();
        let meta = self.checkpoint_meta(step, snap.counters.clone());
        let frames = self.checkpoint_frames(&snap);
        checkpoint::save_delta(path, &meta, &frames, parent)
    }

    /// Load a checkpoint written by `save_checkpoint` (either the v1
    /// streaming format or the legacy v0 blob, dispatched on the header's
    /// `magic`/`version` keys): restores parameters, the first-order
    /// optimizer state, the second-order preconditioner state (when both
    /// the checkpoint and this run have one), and the resume position — a
    /// subsequent `train` continues at step + 1. Returns the step.
    ///
    /// The restore is **all-or-nothing**: every frame is read and
    /// validated (checksums, codec identity, structure) before any trainer
    /// state is touched, so a corrupt or mismatched checkpoint leaves the
    /// prior state fully intact. It is also bit-exact: codec payloads are
    /// adopted verbatim, so for deterministic codecs the resumed loss
    /// trajectory is identical to an uninterrupted run. Stochastic-rounding
    /// (`-sr`) buffers restore their bytes exactly too, but their in-memory
    /// encode-call counter restarts at zero, so post-resume updates draw a
    /// fresh (still seed-deterministic) rounding stream rather than
    /// replaying the uninterrupted run's — the resumed trajectory is
    /// equivalent in distribution, not bit-identical.
    pub fn load_checkpoint(&mut self, path: &Path) -> Result<usize> {
        match checkpoint::probe_version(path)? {
            None => self.load_checkpoint_v0(path),
            Some(_) => self.load_checkpoint_v1(path),
        }
    }

    /// v1 loader: per-frame positional reads, staged + validated fully
    /// before the all-or-nothing apply.
    fn load_checkpoint_v1(&mut self, path: &Path) -> Result<usize> {
        let ckpt = CheckpointFile::open(path)?;
        let h = &ckpt.header;
        if h.model != self.model.name {
            anyhow::bail!("checkpoint is for {}, trainer has {}", h.model, self.model.name);
        }
        if h.opt != self.first.name() {
            anyhow::bail!(
                "checkpoint optimizer state is for {}, trainer has {}",
                h.opt,
                self.first.name()
            );
        }
        if h.param_count != self.model.param_count() {
            anyhow::bail!(
                "checkpoint has {} parameters, trainer has {}",
                h.param_count,
                self.model.param_count()
            );
        }
        // stage 1: read + structurally validate everything; nothing below
        // touches trainer state until every frame has been checked
        let mut consumed: BTreeSet<String> = BTreeSet::new();
        let mut new_params: Vec<Vec<f32>> = Vec::with_capacity(self.model.params.len());
        for (i, p) in self.model.params.iter().enumerate() {
            let role = format!("param.{i}");
            let entry = match ckpt.frame(&role) {
                Some(e) => e,
                None => return Err(CheckpointError::MissingFrame { role }.into()),
            };
            if entry.codec != "fp32" || entry.len != p.len() {
                return Err(CheckpointError::CorruptFrame {
                    role: role.clone(),
                    detail: format!(
                        "expected fp32@{} (tensor shape), manifest records {}@{}",
                        p.len(),
                        entry.codec,
                        entry.len
                    ),
                }
                .into());
            }
            let bytes = ckpt.read_frame_bytes(&role)?;
            new_params.push(
                bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            );
            consumed.insert(role);
        }
        let mut buffers = Vec::new();
        let mut i = 0usize;
        while let Some(entry) = ckpt.frame(&format!("opt.{i}")) {
            let role = format!("opt.{i}");
            let len = entry.len;
            let codec = entry.codec.clone();
            let bytes = ckpt.read_frame_bytes(&role)?;
            buffers.push((codec, EncodedVec { bytes, len }));
            consumed.insert(role);
            i += 1;
        }
        let snapshot = StateSnapshot { buffers, counters: h.opt_counters.clone() };
        let so_count = h.manifest.iter().filter(|e| e.role.starts_with("so.")).count();
        let mut sides: Vec<(SideState, SideState)> = Vec::new();
        match self.second.as_ref() {
            Some(second) if so_count > 0 => {
                if so_count != second.blocks.len() * 2 {
                    anyhow::bail!(
                        "checkpoint has {so_count} second-order side frames, run expects {}",
                        second.blocks.len() * 2
                    );
                }
                for bi in 0..second.blocks.len() {
                    let left = read_side_frame(&ckpt, bi, "left", &mut consumed)?;
                    let right = read_side_frame(&ckpt, bi, "right", &mut consumed)?;
                    sides.push((left, right));
                }
            }
            None if so_count > 0 => eprintln!(
                "load_checkpoint: checkpoint carries second-order state but this run \
                 has no second-order optimizer; ignoring it"
            ),
            Some(_) => eprintln!(
                "load_checkpoint: checkpoint has no second-order state; statistics \
                 re-warm from initialization over the next T1/T2 cycles"
            ),
            None => {}
        }
        // stage 2: logical validation, still pure
        if let Some(second) = self.second.as_ref() {
            if !sides.is_empty() {
                second.validate_sides(&sides).context("restoring second-order state")?;
            }
        }
        // stage 3: checksum-verify every frame this run does NOT consume
        // (e.g. ignored second-order state), so corruption anywhere in the
        // file fails the load — zero silent restores
        for e in &h.manifest {
            if !consumed.contains(&e.role) {
                ckpt.verify_frame(&e.role)?;
            }
        }
        // stage 4: apply. `import_state` validates everything before
        // mutating, and the sides were pre-validated above, so the only
        // failure mode past this point is shard re-sync IO.
        self.first.import_state(snapshot)?;
        if !sides.is_empty() {
            if let Some(second) = self.second.as_mut() {
                second.apply_sides(sides).context("restoring second-order state")?;
            }
        }
        self.model.params = new_params;
        self.resume_step = h.step;
        Ok(h.step)
    }

    /// Legacy v0 loader (pre-manifest monolithic blob): same staged
    /// all-or-nothing discipline — parse + validate everything, then apply.
    fn load_checkpoint_v0(&mut self, path: &Path) -> Result<usize> {
        use std::io::Read;
        let mut f = std::fs::File::open(path)?;
        let mut all = Vec::new();
        f.read_to_end(&mut all)?;
        let nl = all
            .iter()
            .position(|&b| b == b'\n')
            .context("missing checkpoint header")?;
        let header = Json::parse(std::str::from_utf8(&all[..nl])?)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let model = header.get("model").and_then(|j| j.as_str()).context("model")?;
        if model != self.model.name {
            anyhow::bail!("checkpoint is for {model}, trainer has {}", self.model.name);
        }
        let mut off = nl + 1;
        fn take<'a>(all: &'a [u8], off: &mut usize, n: usize) -> Result<&'a [u8]> {
            if all.len() < *off + n {
                anyhow::bail!("checkpoint truncated at byte {}", *off);
            }
            let s = &all[*off..*off + n];
            *off += n;
            Ok(s)
        }
        let mut new_params = Vec::with_capacity(self.model.params.len());
        for p in &self.model.params {
            let raw = take(&all, &mut off, p.len() * 4)?;
            new_params.push(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect::<Vec<f32>>(),
            );
        }

        let opt = header.get("opt").and_then(|j| j.as_str()).unwrap_or("");
        if opt != self.first.name() {
            anyhow::bail!(
                "checkpoint optimizer state is for {opt}, trainer has {}",
                self.first.name()
            );
        }
        let lens = header
            .get("opt_buffers")
            .and_then(|j| j.usize_vec())
            .context("opt_buffers")?;
        let byte_lens = header
            .get("opt_bytes")
            .and_then(|j| j.usize_vec())
            .context("opt_bytes")?;
        let codecs: Vec<String> = header
            .get("opt_codecs")
            .and_then(|j| j.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
            .context("opt_codecs")?;
        if lens.len() != byte_lens.len() || lens.len() != codecs.len() {
            anyhow::bail!("checkpoint optimizer buffer metadata is inconsistent");
        }
        let counters: Vec<f64> = header
            .get("opt_counters")
            .and_then(|j| j.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
            .unwrap_or_default();
        let mut buffers = Vec::with_capacity(lens.len());
        for ((len, nbytes), codec) in lens.into_iter().zip(byte_lens).zip(codecs) {
            let bytes = take(&all, &mut off, nbytes)?.to_vec();
            buffers.push((codec, EncodedVec { bytes, len }));
        }

        let so_bytes = header
            .get("second_order_bytes")
            .and_then(|j| j.as_usize())
            .unwrap_or(0);
        let mut sides = None;
        if so_bytes > 0 {
            let blob = take(&all, &mut off, so_bytes)?;
            match self.second.as_ref() {
                Some(second) => {
                    let s = second.parse_state(blob).context("restoring second-order state")?;
                    second.validate_sides(&s).context("restoring second-order state")?;
                    sides = Some(s);
                }
                None => eprintln!(
                    "load_checkpoint: checkpoint carries second-order state but this run \
                     has no second-order optimizer; ignoring it"
                ),
            }
        } else if self.second.is_some() {
            eprintln!(
                "load_checkpoint: checkpoint has no second-order state; statistics \
                 re-warm from initialization over the next T1/T2 cycles"
            );
        }
        // all-or-nothing apply: nothing above mutated trainer state, and
        // `import_state` validates its whole snapshot before mutating
        self.first.import_state(StateSnapshot { buffers, counters })?;
        if let Some(s) = sides {
            if let Some(second) = self.second.as_mut() {
                second.apply_sides(s).context("restoring second-order state")?;
            }
        }
        self.model.params = new_params;
        let step = header.get("step").and_then(|j| j.as_usize()).unwrap_or(0);
        self.resume_step = step;
        Ok(step)
    }
}

/// Read + deserialize one `so.{bi}.{tag}` side frame, marking it consumed.
fn read_side_frame(
    ckpt: &CheckpointFile,
    bi: usize,
    tag: &str,
    consumed: &mut BTreeSet<String>,
) -> Result<SideState> {
    let role = format!("so.{bi}.{tag}");
    let bytes = ckpt.read_frame_bytes(&role)?;
    let (s, used) = SideState::deserialize(&bytes).map_err(|err| {
        anyhow::Error::from(CheckpointError::CorruptFrame {
            role: role.clone(),
            detail: format!("{err:#}"),
        })
    })?;
    if used != bytes.len() {
        return Err(CheckpointError::CorruptFrame {
            role,
            detail: format!("{} trailing bytes after the side state", bytes.len() - used),
        }
        .into());
    }
    consumed.insert(role);
    Ok(s)
}

/// Convenience: NRE between two host matrices (re-export for shadow users).
pub use errors::nre as matrix_nre;
