//! The streaming checkpoint format (v1) and the read-only state server.
//!
//! A v1 checkpoint is two text lines followed by a binary payload:
//!
//! ```text
//! {"magic":"shampoo4-ckpt","version":1,...,"manifest":[...]}\n
//! #crc32:xxxxxxxx\n
//! <frame 0 bytes><frame 1 bytes>...
//! ```
//!
//! Line 1 is a compact JSON header carrying run identity (model, step,
//! optimizer, counters, quant policy) plus the **manifest**: one row per
//! buffer with its `role` (e.g. `param.0`, `opt.1`, `so.3.left`), codec
//! name, decoded element count, byte length, payload-relative offset, and
//! CRC-32. Line 2 records the CRC-32 of line 1, so header corruption is as
//! detectable as payload corruption. Frames tile the payload exactly, in
//! manifest order, with no gaps and no trailing bytes.
//!
//! **Streaming:** [`save`] never materializes the whole state — each frame
//! is produced chunk-by-chunk through its [`FrameSpec::emit`] callback
//! (once to size + checksum it, once to write it) and flows through a
//! buffered writer. Reads are per-frame positional IO; the payload is never
//! loaded whole.
//!
//! **Crash atomicity:** the file is written to `<path>.tmp`, fsynced,
//! then renamed over `path` (plus a best-effort directory fsync), so a
//! crash mid-save leaves either the old checkpoint or the new one — never
//! a torn file at the final path.
//!
//! **Delta checkpoints:** [`save_delta`] records a frame as `in_parent`
//! (and skips rewriting its bytes) when its bytes are identical to the
//! parent checkpoint's — checked by CRC *and* a streaming byte compare.
//! The child's manifest still lists every frame, so it remains the single
//! source of truth; readers resolve `in_parent` frames through the
//! `parent` path (depth- and cycle-checked, identity re-verified at every
//! hop). Quantized codec bytes only change when a buffer is actually
//! rewritten (e.g. second-order sides between T1 boundaries), which is
//! what makes deltas worthwhile.
//!
//! **Fault model:** every structural defect maps to a typed
//! [`CheckpointError`] naming the frame/offset involved — truncation,
//! bit-flips (header or payload), foreign magic, unknown versions, broken
//! parent chains. There is no code path that silently zero-decodes or
//! partially restores; `tests/checkpoint_faults.rs` proves it by injecting
//! faults at every frame boundary.
//!
//! [`StateServer`] serves decoded slices of any buffer to many concurrent
//! readers straight from the framed file: positional reads (`pread` on
//! unix; no locks anywhere) of just the bytes whose quantization blocks
//! cover the requested range, decoded through the existing 256-entry
//! tables via [`StateCodec::slice_ranges`].

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::fs;
use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::quant::{codec_by_name, crc32, Crc32, EncodedVec, StateCodec};
use crate::util::json::Json;

/// Magic string identifying a v1+ streaming checkpoint header.
pub const MAGIC: &str = "shampoo4-ckpt";

/// Newest header version this build writes and understands.
pub const VERSION: u64 = 1;

/// Manifest codec name for opaque second-order side frames: their payload
/// is a self-describing [`SideState`](crate::coordinator::state::SideState)
/// serialization, not a bare codec buffer, so the server hands them out as
/// raw bytes only.
pub const SIDE_STATE_CODEC: &str = "side-state";

/// Delta chains longer than this are rejected (runaway/cyclic protection
/// beyond the explicit cycle check).
const MAX_PARENT_DEPTH: usize = 32;

/// Chunk size for streaming checksum verification.
const VERIFY_CHUNK: usize = 64 * 1024;

// ---------------------------------------------------------------------------
// error taxonomy

/// Typed failure taxonomy for the v1 checkpoint format. Every corrupt or
/// foreign file maps to one of these (carried inside `anyhow::Error`),
/// naming the frame/offset involved — never a silent zero-decode, never a
/// partial restore.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CheckpointError {
    /// The `magic` header key is present but is not ours.
    BadMagic {
        /// The magic value found in the file.
        found: String,
    },
    /// `magic` matched but the declared `version` is unknown to this build.
    UnsupportedVersion {
        /// The version the file declares.
        version: u64,
    },
    /// The file ends inside the two header lines.
    TruncatedHeader {
        /// What was being read when the bytes ran out.
        detail: String,
    },
    /// The header line does not match its recorded `#crc32:` line.
    HeaderChecksum {
        /// CRC-32 recorded on the checksum line.
        expected: u32,
        /// CRC-32 computed over the header line actually on disk.
        found: u32,
    },
    /// Structurally invalid header: bad JSON, missing/mistyped keys,
    /// malformed checksum line, or a manifest that does not tile the
    /// payload.
    BadHeader {
        /// What is wrong.
        detail: String,
    },
    /// The payload ends inside a manifest frame.
    Truncated {
        /// Role of the first frame extending past end-of-file.
        role: String,
        /// The frame's payload-relative byte offset.
        offset: u64,
        /// Bytes the manifest says the frame occupies.
        need: u64,
        /// Payload bytes actually present from the frame's offset on.
        have: u64,
    },
    /// A frame's bytes do not match the manifest checksum.
    ChecksumMismatch {
        /// Role of the corrupt frame.
        role: String,
        /// The frame's payload-relative byte offset.
        offset: u64,
        /// CRC-32 recorded in the manifest.
        expected: u32,
        /// CRC-32 computed over the bytes on disk.
        found: u32,
    },
    /// A frame that passed its checksum failed structural validation, or
    /// could not be read at all.
    CorruptFrame {
        /// Role of the offending frame.
        role: String,
        /// What is wrong.
        detail: String,
    },
    /// A role the reader requires is absent from the manifest.
    MissingFrame {
        /// The absent role.
        role: String,
    },
    /// The file is longer than the manifest accounts for.
    TrailingBytes {
        /// File length the manifest accounts for.
        expected: u64,
        /// Actual file length.
        found: u64,
    },
    /// A delta checkpoint's parent chain cannot be resolved.
    ParentChain {
        /// The chain path involved.
        path: String,
        /// What went wrong.
        detail: String,
    },
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::BadMagic { found } => {
                write!(f, "not a {MAGIC} checkpoint: header magic is {found:?}")
            }
            CheckpointError::UnsupportedVersion { version } => write!(
                f,
                "checkpoint version {version} is not supported by this build \
                 (newest known: {VERSION})"
            ),
            CheckpointError::TruncatedHeader { detail } => {
                write!(f, "checkpoint header truncated: {detail}")
            }
            CheckpointError::HeaderChecksum { expected, found } => write!(
                f,
                "checkpoint header failed its checksum: recorded {expected:#010x}, \
                 computed {found:#010x}"
            ),
            CheckpointError::BadHeader { detail } => {
                write!(f, "checkpoint header is invalid: {detail}")
            }
            CheckpointError::Truncated { role, offset, need, have } => write!(
                f,
                "checkpoint frame {role:?} at payload offset {offset} is truncated: \
                 needs {need} bytes, file has {have}"
            ),
            CheckpointError::ChecksumMismatch { role, offset, expected, found } => write!(
                f,
                "checkpoint frame {role:?} at payload offset {offset} failed its \
                 checksum: recorded {expected:#010x}, computed {found:#010x}"
            ),
            CheckpointError::CorruptFrame { role, detail } => {
                write!(f, "checkpoint frame {role:?} is corrupt: {detail}")
            }
            CheckpointError::MissingFrame { role } => {
                write!(f, "checkpoint has no frame for role {role:?}")
            }
            CheckpointError::TrailingBytes { expected, found } => write!(
                f,
                "checkpoint has trailing bytes: manifest accounts for {expected} \
                 bytes, file has {found}"
            ),
            CheckpointError::ParentChain { path, detail } => {
                write!(f, "checkpoint parent chain via {path:?} is broken: {detail}")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

fn bad(detail: impl Into<String>) -> CheckpointError {
    CheckpointError::BadHeader { detail: detail.into() }
}

// ---------------------------------------------------------------------------
// header + manifest

/// One manifest row: where a buffer's codec bytes live and how to check
/// them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrameEntry {
    /// Stable buffer identity (`param.0`, `opt.1`, `so.3.left`, ...).
    pub role: String,
    /// Codec the bytes decode through (`codec_by_name`), or
    /// [`SIDE_STATE_CODEC`] for opaque side frames.
    pub codec: String,
    /// Element count of the decoded buffer (0 for opaque frames).
    pub len: usize,
    /// Byte length of the frame payload.
    pub bytes: u64,
    /// Payload-relative byte offset (0 when `in_parent`).
    pub offset: u64,
    /// CRC-32 of the frame bytes.
    pub crc32: u32,
    /// Delta checkpoints: the bytes live in the parent chain, not here.
    pub in_parent: bool,
}

/// Parsed v1 header: run identity plus the frame manifest.
#[derive(Debug, Clone)]
pub struct Header {
    /// Header format version (currently always 1).
    pub version: u64,
    /// Model name the checkpoint belongs to.
    pub model: String,
    /// Last completed training step.
    pub step: usize,
    /// Total model parameter count.
    pub param_count: usize,
    /// First-order optimizer name.
    pub opt: String,
    /// First-order scalar counters (bias-correction steps etc.).
    pub opt_counters: Vec<f64>,
    /// The run's configured role→codec policy summary ("" = single knobs).
    pub quant_policy: String,
    /// Shard count at save time (observability only — restores are
    /// shard-count-portable by construction).
    pub shards: usize,
    /// Delta checkpoints: path of the parent (relative paths resolve
    /// against this file's directory).
    pub parent: Option<String>,
    /// The frame manifest, in payload order.
    pub manifest: Vec<FrameEntry>,
}

impl Header {
    fn from_json(j: &Json) -> Result<Header> {
        fn req_str(j: &Json, key: &str) -> Result<String> {
            j.get(key)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .ok_or_else(|| bad(format!("missing string key {key:?}")).into())
        }
        fn req_usize(j: &Json, key: &str) -> Result<usize> {
            j.get(key)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| bad(format!("missing numeric key {key:?}")).into())
        }
        let version = req_usize(j, "version")? as u64;
        let opt_counters: Vec<f64> = j
            .get("opt_counters")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
            .unwrap_or_default();
        let parent = j.get("parent").and_then(|v| v.as_str()).map(str::to_string);
        let rows = j
            .get("manifest")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| bad("missing manifest array"))?;
        let mut manifest = Vec::with_capacity(rows.len());
        let mut seen = BTreeSet::new();
        for (i, row) in rows.iter().enumerate() {
            let role = req_str(row, "role")
                .map_err(|e| bad(format!("manifest row {i}: {e:#}")))?;
            if !seen.insert(role.clone()) {
                return Err(bad(format!("duplicate manifest role {role:?}")).into());
            }
            manifest.push(FrameEntry {
                codec: req_str(row, "codec")
                    .map_err(|e| bad(format!("manifest row {i}: {e:#}")))?,
                len: req_usize(row, "len")
                    .map_err(|e| bad(format!("manifest row {i}: {e:#}")))?,
                bytes: req_usize(row, "bytes")
                    .map_err(|e| bad(format!("manifest row {i}: {e:#}")))? as u64,
                offset: req_usize(row, "offset")
                    .map_err(|e| bad(format!("manifest row {i}: {e:#}")))? as u64,
                crc32: req_usize(row, "crc32")
                    .map_err(|e| bad(format!("manifest row {i}: {e:#}")))? as u32,
                in_parent: row.get("in_parent").and_then(|v| v.as_bool()).unwrap_or(false),
                role,
            });
        }
        Ok(Header {
            version,
            model: req_str(j, "model")?,
            step: req_usize(j, "step")?,
            param_count: req_usize(j, "param_count")?,
            opt: req_str(j, "opt")?,
            opt_counters,
            quant_policy: j
                .get("quant_policy")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            shards: j.get("shards").and_then(|v| v.as_usize()).unwrap_or(1),
            parent,
            manifest,
        })
    }
}

// ---------------------------------------------------------------------------
// writer

/// Streaming payload producer: feeds the sink consecutive byte chunks of
/// one frame. Must be deterministic — the writer runs it once to size and
/// checksum the frame, possibly once to delta-compare against the parent,
/// and once to write.
pub type FrameEmit<'a> = Box<dyn Fn(&mut dyn FnMut(&[u8])) + 'a>;

/// How one buffer enters the checkpoint: manifest identity plus a
/// streaming payload producer.
pub struct FrameSpec<'a> {
    /// Stable buffer identity (see [`FrameEntry::role`]).
    pub role: String,
    /// Codec name recorded in the manifest.
    pub codec: String,
    /// Decoded element count recorded in the manifest (0 for opaque).
    pub len: usize,
    /// Streaming payload producer.
    pub emit: FrameEmit<'a>,
}

/// Run identity recorded in the header (everything except the manifest).
#[derive(Debug, Clone)]
pub struct CheckpointMeta {
    /// Model name.
    pub model: String,
    /// Last completed training step.
    pub step: usize,
    /// Total model parameter count.
    pub param_count: usize,
    /// First-order optimizer name.
    pub opt: String,
    /// First-order scalar counters.
    pub opt_counters: Vec<f64>,
    /// Role→codec policy summary ("" = single knobs).
    pub quant_policy: String,
    /// Shard count at save time.
    pub shards: usize,
}

/// Write a monolithic v1 checkpoint: every frame's bytes are present in
/// this one file. Atomic: streams through `<path>.tmp` + fsync + rename.
pub fn save(path: &Path, meta: &CheckpointMeta, frames: &[FrameSpec<'_>]) -> Result<()> {
    write_file(path, meta, frames, None)
}

/// Write a delta v1 checkpoint against `parent`: frames whose bytes are
/// byte-identical to the parent's resolution of the same role are recorded
/// `in_parent` and not rewritten. The manifest still lists every frame, so
/// the child alone fully describes the state; readers chase the `parent`
/// path only for the skipped bytes. Same atomicity as [`save`].
pub fn save_delta(
    path: &Path,
    meta: &CheckpointMeta,
    frames: &[FrameSpec<'_>],
    parent: &Path,
) -> Result<()> {
    write_file(path, meta, frames, Some(parent))
}

fn write_file(
    path: &Path,
    meta: &CheckpointMeta,
    frames: &[FrameSpec<'_>],
    parent: Option<&Path>,
) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    // pass 1: stream every frame once to learn its byte count + checksum
    let mut sized: Vec<FrameEntry> = Vec::with_capacity(frames.len());
    for fr in frames {
        let mut crc = Crc32::new();
        let mut nbytes = 0u64;
        (fr.emit)(&mut |chunk| {
            crc.update(chunk);
            nbytes += chunk.len() as u64;
        });
        sized.push(FrameEntry {
            role: fr.role.clone(),
            codec: fr.codec.clone(),
            len: fr.len,
            bytes: nbytes,
            offset: 0,
            crc32: crc.finish(),
            in_parent: false,
        });
    }
    // delta pass: a frame whose identity AND bytes match the parent's is
    // recorded `in_parent` and its payload skipped
    let mut stored_parent = None;
    if let Some(ppath) = parent {
        let pfile = CheckpointFile::open(ppath)
            .with_context(|| format!("opening delta parent {}", ppath.display()))?;
        for (fr, entry) in frames.iter().zip(sized.iter_mut()) {
            let same_id = match pfile.frame(&entry.role) {
                Some(pe) => {
                    pe.codec == entry.codec
                        && pe.len == entry.len
                        && pe.bytes == entry.bytes
                        && pe.crc32 == entry.crc32
                }
                None => false,
            };
            if !same_id {
                continue;
            }
            // CRC equality is necessary but not sufficient: stream-compare
            // the actual bytes so a collision can never silently alias state
            let pbytes = pfile.read_frame_bytes(&entry.role)?;
            let mut pos = 0usize;
            let mut equal = true;
            (fr.emit)(&mut |chunk| {
                let end = pos + chunk.len();
                if end > pbytes.len() || &pbytes[pos..end] != chunk {
                    equal = false;
                }
                pos = end;
            });
            if equal && pos == pbytes.len() {
                entry.in_parent = true;
            }
        }
        if sized.iter().any(|e| e.in_parent) {
            stored_parent = Some(stored_parent_path(path, ppath)?);
        }
    }
    // assign payload offsets to the frames physically present here
    let mut running = 0u64;
    for e in sized.iter_mut() {
        if e.in_parent {
            continue;
        }
        e.offset = running;
        running += e.bytes;
    }
    let header_line = header_to_json(meta, stored_parent.as_deref(), &sized).to_string();
    let crc_line = format!("#crc32:{:08x}", crc32(header_line.as_bytes()));

    let tmp = tmp_path(path);
    if let Err(e) = write_tmp(&tmp, &header_line, &crc_line, frames, &sized) {
        let _ = fs::remove_file(&tmp);
        return Err(e);
    }
    fs::rename(&tmp, path).with_context(|| format!("committing {}", path.display()))?;
    sync_parent_dir(path);
    Ok(())
}

/// Pass 2: stream every present frame into `<path>.tmp` and fsync it.
fn write_tmp(
    tmp: &Path,
    header_line: &str,
    crc_line: &str,
    frames: &[FrameSpec<'_>],
    sized: &[FrameEntry],
) -> Result<()> {
    let f = fs::File::create(tmp).with_context(|| format!("creating {}", tmp.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "{header_line}")?;
    writeln!(w, "{crc_line}")?;
    for (fr, entry) in frames.iter().zip(sized) {
        if entry.in_parent {
            continue;
        }
        let mut written = 0u64;
        let mut io_err: Option<std::io::Error> = None;
        (fr.emit)(&mut |chunk| {
            if io_err.is_some() {
                return;
            }
            if let Err(e) = w.write_all(chunk) {
                io_err = Some(e);
                return;
            }
            written += chunk.len() as u64;
        });
        if let Some(e) = io_err {
            return Err(e.into());
        }
        if written != entry.bytes {
            anyhow::bail!(
                "checkpoint frame {:?} changed size between passes: sized {} bytes, \
                 wrote {} (emit must be deterministic)",
                entry.role,
                entry.bytes,
                written
            );
        }
    }
    w.flush()?;
    let f = w.into_inner().map_err(|e| anyhow::anyhow!("flushing checkpoint writer: {e}"))?;
    f.sync_all()?;
    Ok(())
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// Best-effort directory fsync so the rename itself is durable (POSIX
/// crash-atomicity; failure here degrades durability, never correctness).
fn sync_parent_dir(path: &Path) {
    #[cfg(unix)]
    {
        let dir = match path.parent() {
            Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
            _ => PathBuf::from("."),
        };
        if let Ok(d) = fs::File::open(&dir) {
            let _ = d.sync_all();
        }
    }
    #[cfg(not(unix))]
    let _ = path;
}

/// The parent path recorded in a delta header: just the file name when
/// parent and child share a directory (so checkpoint dirs stay
/// relocatable), the canonical absolute path otherwise.
fn stored_parent_path(child: &Path, parent: &Path) -> Result<String> {
    let p = if child.parent() == parent.parent() {
        match parent.file_name() {
            Some(n) => PathBuf::from(n),
            None => parent.to_path_buf(),
        }
    } else {
        fs::canonicalize(parent)
            .with_context(|| format!("canonicalizing delta parent {}", parent.display()))?
    };
    match p.to_str() {
        Some(s) => Ok(s.to_string()),
        None => anyhow::bail!("delta parent path {} is not valid UTF-8", p.display()),
    }
}

fn header_to_json(meta: &CheckpointMeta, parent: Option<&str>, manifest: &[FrameEntry]) -> Json {
    let rows: Vec<Json> = manifest
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("role", Json::Str(e.role.clone())),
                ("codec", Json::Str(e.codec.clone())),
                ("len", Json::Num(e.len as f64)),
                ("bytes", Json::Num(e.bytes as f64)),
                ("offset", Json::Num(e.offset as f64)),
                ("crc32", Json::Num(e.crc32 as f64)),
                ("in_parent", Json::Bool(e.in_parent)),
            ])
        })
        .collect();
    let mut pairs = vec![
        ("magic", Json::Str(MAGIC.to_string())),
        ("version", Json::Num(VERSION as f64)),
        ("model", Json::Str(meta.model.clone())),
        ("step", Json::Num(meta.step as f64)),
        ("param_count", Json::Num(meta.param_count as f64)),
        ("opt", Json::Str(meta.opt.clone())),
        ("opt_counters", Json::arr_f64(&meta.opt_counters)),
        ("quant_policy", Json::Str(meta.quant_policy.clone())),
        ("shards", Json::Num(meta.shards as f64)),
        ("manifest", Json::Arr(rows)),
    ];
    if let Some(p) = parent {
        pairs.push(("parent", Json::Str(p.to_string())));
    }
    Json::obj(pairs)
}

// ---------------------------------------------------------------------------
// reader

/// Probe a checkpoint's header version without touching the payload:
/// `Ok(None)` = legacy v0 (JSON header with no `magic` key), `Ok(Some(v))`
/// = v1 streaming format. Foreign magic and unknown versions are typed
/// errors, not `None`.
pub fn probe_version(path: &Path) -> Result<Option<u64>> {
    let f = fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let line = read_header_line(&mut r, "header line")?;
    let j = Json::parse(&line).map_err(|e| bad(format!("header is not JSON: {e}")))?;
    let magic = match j.get("magic").and_then(|v| v.as_str()) {
        Some(m) => m.to_string(),
        None => return Ok(None),
    };
    if magic != MAGIC {
        return Err(CheckpointError::BadMagic { found: magic }.into());
    }
    let version = j
        .get("version")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| bad("magic without a version key"))? as u64;
    if version != VERSION {
        return Err(CheckpointError::UnsupportedVersion { version }.into());
    }
    Ok(Some(version))
}

fn read_header_line(r: &mut impl BufRead, what: &str) -> Result<String> {
    let mut buf = Vec::new();
    let n = r.read_until(b'\n', &mut buf)?;
    if n == 0 || buf.last() != Some(&b'\n') {
        return Err(CheckpointError::TruncatedHeader {
            detail: format!("missing newline after {what}"),
        }
        .into());
    }
    buf.pop();
    match String::from_utf8(buf) {
        Ok(s) => Ok(s),
        Err(_) => Err(bad(format!("{what} is not UTF-8")).into()),
    }
}

fn parse_crc_line(line: &str) -> Result<u32> {
    let malformed = || bad("malformed #crc32 checksum line");
    let hex = match line.strip_prefix("#crc32:") {
        Some(h) => h,
        None => return Err(malformed().into()),
    };
    if hex.len() != 8 || !hex.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(malformed().into());
    }
    match u32::from_str_radix(hex, 16) {
        Ok(v) => Ok(v),
        Err(_) => Err(malformed().into()),
    }
}

fn resolve_parent_path(child: &Path, stored: &str) -> PathBuf {
    let p = Path::new(stored);
    if p.is_absolute() {
        return p.to_path_buf();
    }
    match child.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.join(p),
        _ => p.to_path_buf(),
    }
}

/// Sequential positional read: open, seek, fill `buf` exactly.
fn read_exact_at_path(path: &Path, off: u64, buf: &mut [u8]) -> std::io::Result<()> {
    let mut f = fs::File::open(path)?;
    f.seek(SeekFrom::Start(off))?;
    f.read_exact(buf)
}

/// An opened v1 checkpoint: validated header plus the resolved delta-parent
/// chain. Structure (header checksum, manifest tiling, payload length,
/// chain identity) is verified at open; frame payload checksums are
/// verified on every read. All reads are per-frame positional IO — the
/// payload is never loaded whole.
pub struct CheckpointFile {
    path: PathBuf,
    /// The parsed, validated header.
    pub header: Header,
    payload_start: u64,
    parent: Option<Box<CheckpointFile>>,
}

impl CheckpointFile {
    /// Open and structurally validate `path` (and its delta-parent chain,
    /// depth- and cycle-checked).
    pub fn open(path: &Path) -> Result<Self> {
        let mut visited = BTreeSet::new();
        Self::open_chain(path, &mut visited, 0)
    }

    fn open_chain(path: &Path, visited: &mut BTreeSet<PathBuf>, depth: usize) -> Result<Self> {
        if depth > MAX_PARENT_DEPTH {
            return Err(CheckpointError::ParentChain {
                path: path.display().to_string(),
                detail: format!("chain deeper than {MAX_PARENT_DEPTH}"),
            }
            .into());
        }
        let canon = fs::canonicalize(path).unwrap_or_else(|_| path.to_path_buf());
        if !visited.insert(canon) {
            return Err(CheckpointError::ParentChain {
                path: path.display().to_string(),
                detail: "cycle in delta-parent chain".to_string(),
            }
            .into());
        }
        let f = fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?;
        let file_len = f.metadata()?.len();
        let mut r = BufReader::new(f);
        let header_line = read_header_line(&mut r, "header line")?;
        let crc_line = read_header_line(&mut r, "checksum line")?;
        let expected = parse_crc_line(&crc_line)?;
        let found = crc32(header_line.as_bytes());
        if expected != found {
            return Err(CheckpointError::HeaderChecksum { expected, found }.into());
        }
        let j = Json::parse(&header_line).map_err(|e| bad(format!("header is not JSON: {e}")))?;
        match j.get("magic").and_then(|v| v.as_str()) {
            Some(m) if m == MAGIC => {}
            Some(m) => return Err(CheckpointError::BadMagic { found: m.to_string() }.into()),
            None => return Err(bad("missing magic key (legacy v0 checkpoint?)").into()),
        }
        let version = j
            .get("version")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| bad("missing version key"))? as u64;
        if version != VERSION {
            return Err(CheckpointError::UnsupportedVersion { version }.into());
        }
        let header = Header::from_json(&j)?;
        let payload_start = header_line.len() as u64 + 1 + crc_line.len() as u64 + 1;
        let payload_len = file_len.saturating_sub(payload_start);
        // present frames must tile the payload exactly, in manifest order
        let mut running = 0u64;
        for e in header.manifest.iter().filter(|e| !e.in_parent) {
            if e.offset != running {
                return Err(bad(format!(
                    "frame {:?} at offset {} breaks the manifest tiling (expected {running})",
                    e.role, e.offset
                ))
                .into());
            }
            if e.offset + e.bytes > payload_len {
                return Err(CheckpointError::Truncated {
                    role: e.role.clone(),
                    offset: e.offset,
                    need: e.bytes,
                    have: payload_len.saturating_sub(e.offset),
                }
                .into());
            }
            running += e.bytes;
        }
        if running < payload_len {
            return Err(CheckpointError::TrailingBytes {
                expected: payload_start + running,
                found: file_len,
            }
            .into());
        }
        let parent = if header.manifest.iter().any(|e| e.in_parent) {
            let pstr = match header.parent.clone() {
                Some(p) => p,
                None => {
                    return Err(bad("manifest has in_parent frames but no parent key").into())
                }
            };
            let ppath = resolve_parent_path(path, &pstr);
            let pfile = Self::open_chain(&ppath, visited, depth + 1).map_err(|e| {
                anyhow::Error::from(CheckpointError::ParentChain {
                    path: pstr.clone(),
                    detail: format!("{e:#}"),
                })
            })?;
            Some(Box::new(pfile))
        } else {
            None
        };
        let file = Self { path: path.to_path_buf(), header, payload_start, parent };
        // every delegated frame must resolve (identity-checked) through the
        // chain now, not at first read
        for e in file.header.manifest.iter().filter(|e| e.in_parent) {
            file.locate(&e.role)?;
        }
        Ok(file)
    }

    /// The file this view reads from.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Absolute file offset where the payload begins (manifest frame
    /// offsets are relative to this). The fault-injection suite uses it to
    /// target exact frame boundaries.
    pub fn payload_offset(&self) -> u64 {
        self.payload_start
    }

    /// This file's manifest row for `role`, if any (even when the bytes
    /// live in the parent chain).
    pub fn frame(&self, role: &str) -> Option<&FrameEntry> {
        self.header.manifest.iter().find(|e| e.role == role)
    }

    /// Resolve `role` to the chain file that physically stores its bytes.
    /// The child's manifest entry is authoritative: the storing ancestor
    /// must agree on codec, element count, byte length, and checksum.
    fn locate(&self, role: &str) -> Result<(&CheckpointFile, &FrameEntry)> {
        let e = match self.frame(role) {
            Some(e) => e,
            None => return Err(CheckpointError::MissingFrame { role: role.to_string() }.into()),
        };
        if !e.in_parent {
            return Ok((self, e));
        }
        let parent = match self.parent.as_deref() {
            Some(p) => p,
            None => {
                return Err(CheckpointError::ParentChain {
                    path: self.path.display().to_string(),
                    detail: format!("frame {role:?} is in_parent but no parent is open"),
                }
                .into())
            }
        };
        let (file, pe) = parent.locate(role)?;
        if pe.codec != e.codec || pe.len != e.len || pe.bytes != e.bytes || pe.crc32 != e.crc32 {
            return Err(CheckpointError::ParentChain {
                path: file.path.display().to_string(),
                detail: format!(
                    "frame {role:?} identity diverged along the chain: child records \
                     {}@{} ({} bytes, crc {:#010x}), ancestor stores {}@{} ({} bytes, \
                     crc {:#010x})",
                    e.codec, e.len, e.bytes, e.crc32, pe.codec, pe.len, pe.bytes, pe.crc32
                ),
            }
            .into());
        }
        Ok((file, pe))
    }

    /// `(path, absolute offset, byte length)` of `role`'s stored bytes in
    /// the chain file that holds them.
    pub fn frame_location(&self, role: &str) -> Result<(PathBuf, u64, u64)> {
        let (file, e) = self.locate(role)?;
        Ok((file.path.clone(), file.payload_start + e.offset, e.bytes))
    }

    /// Read and checksum-verify one frame's raw bytes, resolving through
    /// the delta chain.
    pub fn read_frame_bytes(&self, role: &str) -> Result<Vec<u8>> {
        let (file, e) = self.locate(role)?;
        let mut buf = vec![0u8; e.bytes as usize];
        read_exact_at_path(&file.path, file.payload_start + e.offset, &mut buf).map_err(
            |err| {
                anyhow::Error::from(CheckpointError::CorruptFrame {
                    role: role.to_string(),
                    detail: format!(
                        "reading {} bytes at payload offset {}: {err}",
                        e.bytes, e.offset
                    ),
                })
            },
        )?;
        let found = crc32(&buf);
        if found != e.crc32 {
            return Err(CheckpointError::ChecksumMismatch {
                role: role.to_string(),
                offset: e.offset,
                expected: e.crc32,
                found,
            }
            .into());
        }
        Ok(buf)
    }

    /// Read one frame as an [`EncodedVec`] ready for codec decode.
    pub fn read_frame_encoded(&self, role: &str) -> Result<EncodedVec> {
        let len = self.locate(role)?.1.len;
        let bytes = self.read_frame_bytes(role)?;
        Ok(EncodedVec { bytes, len })
    }

    /// Checksum-verify one frame without materializing it (chunked reads).
    pub fn verify_frame(&self, role: &str) -> Result<()> {
        let (file, e) = self.locate(role)?;
        let mut f = fs::File::open(&file.path)?;
        f.seek(SeekFrom::Start(file.payload_start + e.offset))?;
        let mut crc = Crc32::new();
        let mut remaining = e.bytes;
        let mut chunk = vec![0u8; VERIFY_CHUNK.min((e.bytes.max(1)) as usize)];
        while remaining > 0 {
            let take = chunk.len().min(remaining as usize);
            f.read_exact(&mut chunk[..take]).map_err(|err| {
                anyhow::Error::from(CheckpointError::CorruptFrame {
                    role: role.to_string(),
                    detail: format!("reading {take} bytes at payload offset {}: {err}", e.offset),
                })
            })?;
            crc.update(&chunk[..take]);
            remaining -= take as u64;
        }
        let found = crc.finish();
        if found != e.crc32 {
            return Err(CheckpointError::ChecksumMismatch {
                role: role.to_string(),
                offset: e.offset,
                expected: e.crc32,
                found,
            }
            .into());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// the read-only state server

/// Shared read handle for concurrent serving: positional reads with no
/// shared mutable state — `pread` on unix, an ephemeral handle per call
/// elsewhere. No locks anywhere, so readers never serialize on each other.
struct ServerFile {
    path: PathBuf,
    #[cfg(unix)]
    handle: fs::File,
}

impl ServerFile {
    fn open(path: &Path) -> Result<Self> {
        Ok(Self {
            path: path.to_path_buf(),
            #[cfg(unix)]
            handle: fs::File::open(path)?,
        })
    }

    fn read_exact_at(&self, off: u64, buf: &mut [u8]) -> Result<()> {
        #[cfg(unix)]
        let res = {
            use std::os::unix::fs::FileExt;
            self.handle.read_exact_at(buf, off)
        };
        #[cfg(not(unix))]
        let res = read_exact_at_path(&self.path, off, buf);
        let n = buf.len();
        res.with_context(|| {
            format!("reading {n} bytes at offset {off} from {}", self.path.display())
        })
    }
}

/// Per-role serving metadata: resolved codec, decoded length, and the
/// absolute byte range in whichever chain file stores the frame.
struct ServedFrame {
    /// `None` for opaque [`SIDE_STATE_CODEC`] frames (raw bytes only).
    codec: Option<Arc<dyn StateCodec>>,
    len: usize,
    bytes: u64,
    abs_offset: u64,
    file: Arc<ServerFile>,
}

/// Read-only concurrent state server over a framed checkpoint: many reader
/// threads (`StateServer` is `Send + Sync`; share it behind an `Arc`) pull
/// decoded slices of any buffer straight from the file. Every frame
/// checksum is verified once at open; a slice read afterwards touches only
/// the bytes whose quantization blocks cover the requested range
/// ([`StateCodec::slice_ranges`]) and decodes them through the existing
/// 256-entry tables.
pub struct StateServer {
    frames: BTreeMap<String, ServedFrame>,
}

impl StateServer {
    /// Open a checkpoint for serving: validates structure, checksums every
    /// frame (chunked — nothing is materialized), resolves every decodable
    /// frame's codec, and pins one positional-read handle per chain file.
    pub fn open(path: &Path) -> Result<Self> {
        let ckpt = CheckpointFile::open(path)?;
        let mut files: BTreeMap<PathBuf, Arc<ServerFile>> = BTreeMap::new();
        let mut frames = BTreeMap::new();
        for e in &ckpt.header.manifest {
            ckpt.verify_frame(&e.role)?;
            let (fpath, abs_offset, bytes) = ckpt.frame_location(&e.role)?;
            let file = if let Some(f) = files.get(&fpath) {
                Arc::clone(f)
            } else {
                let f = Arc::new(ServerFile::open(&fpath)?);
                files.insert(fpath, Arc::clone(&f));
                f
            };
            let codec = if e.codec == SIDE_STATE_CODEC {
                None
            } else {
                let c = codec_by_name(&e.codec).map_err(|err| {
                    anyhow::Error::from(CheckpointError::CorruptFrame {
                        role: e.role.clone(),
                        detail: format!("unknown codec {:?}: {err:#}", e.codec),
                    })
                })?;
                Some(c)
            };
            frames.insert(
                e.role.clone(),
                ServedFrame { codec, len: e.len, bytes, abs_offset, file },
            );
        }
        Ok(Self { frames })
    }

    /// Every servable role, sorted.
    pub fn roles(&self) -> Vec<String> {
        self.frames.keys().cloned().collect()
    }

    fn served(&self, role: &str) -> Result<&ServedFrame> {
        match self.frames.get(role) {
            Some(f) => Ok(f),
            None => Err(CheckpointError::MissingFrame { role: role.to_string() }.into()),
        }
    }

    /// Decoded element count of `role` (0 for opaque side-state frames).
    pub fn frame_len(&self, role: &str) -> Result<usize> {
        Ok(self.served(role)?.len)
    }

    /// Decode `count` elements of `role` starting at element `start`,
    /// reading only the bytes whose quantization blocks cover the slice.
    pub fn serve_slice(&self, role: &str, start: usize, count: usize) -> Result<Vec<f32>> {
        let fr = self.served(role)?;
        let codec = match fr.codec.as_ref() {
            Some(c) => c,
            None => {
                return Err(CheckpointError::CorruptFrame {
                    role: role.to_string(),
                    detail: format!(
                        "{SIDE_STATE_CODEC} frames are opaque; use read_raw for their bytes"
                    ),
                }
                .into())
            }
        };
        if start + count > fr.len {
            anyhow::bail!(
                "slice [{start}, {}) is out of bounds for frame {role:?} of {} elements",
                start + count,
                fr.len
            );
        }
        if count == 0 {
            return Ok(Vec::new());
        }
        let sr = codec.slice_ranges(fr.len, start, count);
        let mut bytes = Vec::with_capacity(sr.total_bytes());
        for r in &sr.ranges {
            let prev = bytes.len();
            bytes.resize(prev + r.len(), 0);
            fr.file.read_exact_at(fr.abs_offset + r.start as u64, &mut bytes[prev..])?;
        }
        let sub = EncodedVec { bytes, len: sr.elem_count };
        let decoded = codec.decode(&sub);
        let local = start - sr.elem_start;
        Ok(decoded[local..local + count].to_vec())
    }

    /// Decode one whole buffer.
    pub fn serve_all(&self, role: &str) -> Result<Vec<f32>> {
        let len = self.served(role)?.len;
        self.serve_slice(role, 0, len)
    }

    /// One frame's raw stored bytes (works for opaque side-state frames
    /// too).
    pub fn read_raw(&self, role: &str) -> Result<Vec<u8>> {
        let fr = self.served(role)?;
        let mut buf = vec![0u8; fr.bytes as usize];
        fr.file.read_exact_at(fr.abs_offset, &mut buf)?;
        Ok(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("shampoo4_ckpt_unit_{name}"));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn frame<'a>(role: &str, codec: &str, len: usize, data: &'a [u8]) -> FrameSpec<'a> {
        FrameSpec {
            role: role.to_string(),
            codec: codec.to_string(),
            len,
            emit: Box::new(move |sink: &mut dyn FnMut(&[u8])| {
                // deliberately chunked to exercise streaming writes
                for c in data.chunks(3) {
                    sink(c);
                }
            }),
        }
    }

    fn meta() -> CheckpointMeta {
        CheckpointMeta {
            model: "m".into(),
            step: 7,
            param_count: 3,
            opt: "adamw".into(),
            opt_counters: vec![7.0],
            quant_policy: String::new(),
            shards: 1,
        }
    }

    fn f32_bytes(v: &[f32]) -> Vec<u8> {
        v.iter().flat_map(|x| x.to_le_bytes()).collect()
    }

    #[test]
    fn save_open_read_roundtrip() {
        let dir = tdir("roundtrip");
        let path = dir.join("c.bin");
        let pdata = f32_bytes(&[1.0, -2.5, 3.0]);
        let odata = vec![9u8, 8, 7, 6];
        let frames =
            vec![frame("param.0", "fp32", 3, &pdata), frame("opt.0", "fp32", 1, &odata)];
        save(&path, &meta(), &frames).unwrap();
        assert!(!tmp_path(&path).exists(), "tmp file must be renamed away");

        assert_eq!(probe_version(&path).unwrap(), Some(1));
        let c = CheckpointFile::open(&path).unwrap();
        assert_eq!(c.header.step, 7);
        assert_eq!(c.header.manifest.len(), 2);
        assert_eq!(c.read_frame_bytes("param.0").unwrap(), pdata);
        assert_eq!(c.read_frame_bytes("opt.0").unwrap(), odata);
        c.verify_frame("param.0").unwrap();
        c.verify_frame("opt.0").unwrap();
        let e = c.read_frame_encoded("param.0").unwrap();
        assert_eq!(e.len, 3);
        let missing = c.read_frame_bytes("nope").unwrap_err();
        assert!(format!("{missing:#}").contains("no frame for role"));
    }

    #[test]
    fn delta_skips_identical_frames_and_resolves_through_parent() {
        let dir = tdir("delta");
        let base = dir.join("base.bin");
        let child = dir.join("child.bin");
        let pdata = f32_bytes(&[1.0, 2.0, 4.0]);
        let o0 = vec![1u8, 2, 3];
        save(
            &base,
            &meta(),
            &[frame("param.0", "fp32", 3, &pdata), frame("opt.0", "fp32", 1, &o0)],
        )
        .unwrap();
        let o1 = vec![5u8, 6, 7];
        save_delta(
            &child,
            &meta(),
            &[frame("param.0", "fp32", 3, &pdata), frame("opt.0", "fp32", 1, &o1)],
            &base,
        )
        .unwrap();
        let c = CheckpointFile::open(&child).unwrap();
        let pe = c.frame("param.0").unwrap();
        assert!(pe.in_parent, "unchanged frame must delegate to the parent");
        assert!(!c.frame("opt.0").unwrap().in_parent);
        assert_eq!(c.read_frame_bytes("param.0").unwrap(), pdata);
        assert_eq!(c.read_frame_bytes("opt.0").unwrap(), o1);
        // the child file holds only the changed frame's bytes
        let child_len = fs::metadata(&child).unwrap().len();
        assert_eq!(child_len, c.payload_offset() + o1.len() as u64);
        // chain is visible to the server too
        let srv = StateServer::open(&child).unwrap();
        assert_eq!(srv.serve_all("param.0").unwrap(), vec![1.0, 2.0, 4.0]);
    }

    #[test]
    fn corrupt_payload_and_header_are_typed_errors() {
        let dir = tdir("corrupt");
        let path = dir.join("c.bin");
        let pdata = f32_bytes(&[0.5, 1.5, 2.5]);
        save(&path, &meta(), &[frame("param.0", "fp32", 3, &pdata)]).unwrap();
        let c = CheckpointFile::open(&path).unwrap();
        let off = c.payload_offset();
        let mut bytes = fs::read(&path).unwrap();

        // flip one payload byte → checksum mismatch naming the frame
        let mut flipped = bytes.clone();
        flipped[off as usize] ^= 0x40;
        fs::write(&path, &flipped).unwrap();
        let c2 = CheckpointFile::open(&path).unwrap();
        let err = c2.read_frame_bytes("param.0").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("param.0") && msg.contains("checksum"), "{msg}");

        // truncate inside the frame → Truncated at open
        fs::write(&path, &bytes[..off as usize + 2]).unwrap();
        let err = CheckpointFile::open(&path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");

        // extend past the manifest → trailing bytes
        let mut longer = bytes.clone();
        longer.push(0);
        fs::write(&path, &longer).unwrap();
        let err = CheckpointFile::open(&path).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");

        // flip one header byte → header checksum error
        bytes[2] ^= 0x01;
        fs::write(&path, &bytes).unwrap();
        let err = CheckpointFile::open(&path).unwrap_err();
        assert!(format!("{err:#}").contains("header"), "{err:#}");
    }

    #[test]
    fn foreign_magic_and_versions_are_named() {
        let dir = tdir("magic");
        let path = dir.join("c.bin");
        fs::write(&path, "{\"magic\":\"other-fmt\",\"version\":1}\n").unwrap();
        let err = probe_version(&path).unwrap_err();
        assert!(format!("{err:#}").contains("other-fmt"));

        let hdr = format!("{{\"magic\":\"{MAGIC}\",\"version\":9}}");
        fs::write(&path, format!("{hdr}\n")).unwrap();
        let err = probe_version(&path).unwrap_err();
        assert!(format!("{err:#}").contains("version 9"));

        // v0: JSON header without magic probes as None
        fs::write(&path, "{\"model\":\"m\"}\n").unwrap();
        assert_eq!(probe_version(&path).unwrap(), None);
    }

    #[test]
    fn server_slices_match_full_decode() {
        let dir = tdir("server");
        let path = dir.join("c.bin");
        let vals: Vec<f32> = (0..130).map(|i| (i as f32) * 0.25 - 16.0).collect();
        let codec = codec_by_name("q4-dt").unwrap();
        let enc = codec.encode(&vals);
        let f = FrameSpec {
            role: "opt.0".to_string(),
            codec: codec.name(),
            len: enc.len,
            emit: Box::new(|sink: &mut dyn FnMut(&[u8])| sink(&enc.bytes)),
        };
        save(&path, &meta(), &[f]).unwrap();
        let srv = StateServer::open(&path).unwrap();
        let full = codec.decode(&enc);
        assert_eq!(srv.serve_all("opt.0").unwrap(), full);
        for (s, n) in [(0usize, 1usize), (63, 2), (64, 64), (100, 30), (129, 1), (7, 0)] {
            assert_eq!(srv.serve_slice("opt.0", s, n).unwrap(), full[s..s + n].to_vec());
        }
        assert!(srv.serve_slice("opt.0", 100, 64).is_err(), "oob slice must fail");
    }
}
