//! Deterministic, seedable PRNG (splitmix64 + xoshiro256**) used everywhere
//! randomness is needed: synthetic data, initialization checks, property
//! tests, spectrum generators. In-tree substrate (no `rand` offline).

/// Deterministic 64-bit PRNG (splitmix64-seeded xoshiro256**) with normal
/// sampling.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded generator (same seed → same stream).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for parallel workers / sub-generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n), unbiased (Lemire multiply-shift + rejection).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n64 = n as u64;
        let threshold = n64.wrapping_neg() % n64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n64 as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard-normal sample.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// `n` standard-normal samples.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Sample from unnormalized weights (Zipfian corpus sampling).
    pub fn weighted(&mut self, cdf: &[f64]) -> usize {
        let total = *cdf.last().expect("empty cdf");
        let x = self.uniform() * total;
        match cdf.binary_search_by(|v| v.partial_cmp(&x).unwrap()) {
            Ok(i) => (i + 1).min(cdf.len() - 1),
            Err(i) => i.min(cdf.len() - 1),
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 20000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 40000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..500 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_respects_masses() {
        let mut r = Rng::new(5);
        let cdf = vec![0.1, 0.1, 1.0]; // item 1 has zero mass
        let mut counts = [0usize; 3];
        for _ in 0..2000 {
            counts[r.weighted(&cdf)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
