//! Mini property-testing harness (in-tree substrate for proptest).
//!
//! `check(name, cases, f)` runs `f` against `cases` independently seeded
//! RNGs; on failure it panics with the reproducing seed. No shrinking —
//! failures report the exact seed, which is enough to replay and debug
//! deterministically (`Rng::new(seed)`).

use super::rng::Rng;

/// Run a property with `cases` random cases. `f` receives a fresh seeded RNG
/// and returns `Err(msg)` (or panics) on property violation.
pub fn check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Base seed is fixed for reproducibility in CI; override via env.
    let base: u64 = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_0001);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Helper: assert two f32 slices are close, with a useful error message.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!(
                "element {i}: {x} vs {y} (|diff|={}, tol={tol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        check("u64 is even or odd", 50, |rng| {
            let x = rng.next_u64();
            if x % 2 == 0 || x % 2 == 1 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn fails_with_seed_in_message() {
        check("always fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn close_checks() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-6, 1e-6).unwrap();
        assert!(assert_close(&[1.0], &[1.1], 1e-3, 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3, 0.0).is_err());
    }
}
