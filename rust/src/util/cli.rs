//! Tiny CLI argument parser (in-tree substrate for clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Non-flag arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag`s seen.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without the binary name).
    /// `bool_flags` names options that never take a value — without a schema
    /// `--verbose cfg.toml` is ambiguous.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I, bool_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments (skipping the binary name).
    pub fn parse(bool_flags: &[&str]) -> Args {
        Self::parse_from(std::env::args().skip(1), bool_flags)
    }

    /// Whether `--name` was passed as a flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Value of `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Value of `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Integer value of `--name`, or `default` (panics on junk).
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer, got {v:?}")))
            .unwrap_or(default)
    }

    /// Float value of `--name`, or `default` (panics on junk).
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be a number, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|x| x.to_string()), &["verbose", "dry-run"])
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["train", "--steps", "100", "--lr=0.1", "--verbose", "cfg.toml"]);
        assert_eq!(a.positional, vec!["train", "cfg.toml"]);
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get_f64("lr", 0.0), 0.1);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--dry-run"]);
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn negative_number_value() {
        let a = parse(&["--shift", "-3.5"]);
        // "-3.5" does not start with "--" so it is consumed as the value
        assert_eq!(a.get_f64("shift", 0.0), -3.5);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("mode", "fast"), "fast");
        assert_eq!(a.get_usize("n", 7), 7);
    }
}
