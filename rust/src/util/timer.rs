//! Timing + micro-benchmark harness (in-tree substrate for criterion).
//!
//! Every `[[bench]]` target uses `BenchRunner`: warmup, fixed-duration
//! timed runs, and robust summary statistics (mean / p50 / p95 / min).
//!
//! This is the **blessed wall-clock module**: the rest of the crate reads
//! time only through [`Stopwatch`], never `Instant::now` directly. That is
//! what makes the determinism contract checkable — `shampoo-lint`'s
//! `det-wallclock` rule and clippy's `disallowed-methods` both flag raw
//! clock reads, and the timings gathered here feed telemetry
//! (`StepTimings`, bench stats), never control flow.

// the one file where raw Instant::now is legal (see module docs)
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

/// Simple scope timer.
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    /// Seconds since start.
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    /// Milliseconds since start.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    /// Whole nanoseconds since start (saturating at `u64::MAX`), for
    /// accumulation into atomic counters.
    pub fn nanos(&self) -> u64 {
        u64::try_from(self.0.elapsed().as_nanos()).unwrap_or(u64::MAX)
    }
}

/// Summary statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Benchmark label.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Mean nanoseconds per iteration.
    pub mean_ns: f64,
    /// Median nanoseconds.
    pub p50_ns: f64,
    /// 95th-percentile nanoseconds.
    pub p95_ns: f64,
    /// Fastest iteration nanoseconds.
    pub min_ns: f64,
}

impl BenchStats {
    /// Mean throughput in bytes/second, given the payload size one
    /// iteration processes — the unit the quant throughput harness records
    /// (`BENCH_quant_simd.json`).
    pub fn bytes_per_sec(&self, bytes: usize) -> f64 {
        if self.mean_ns <= 0.0 {
            return 0.0;
        }
        bytes as f64 * 1e9 / self.mean_ns
    }

    /// One-line row with a throughput column appended.
    pub fn throughput_report(&self, bytes: usize) -> String {
        format!("{}  {}", self.report(), fmt_bytes_per_sec(self.bytes_per_sec(bytes)))
    }

    /// One-line human-readable row.
    pub fn report(&self) -> String {
        fn fmt(ns: f64) -> String {
            if ns < 1e3 {
                format!("{ns:.0} ns")
            } else if ns < 1e6 {
                format!("{:.2} µs", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.3} s", ns / 1e9)
            }
        }
        format!(
            "{:<40} mean {:>10}  p50 {:>10}  p95 {:>10}  min {:>10}  ({} iters)",
            self.name,
            fmt(self.mean_ns),
            fmt(self.p50_ns),
            fmt(self.p95_ns),
            fmt(self.min_ns),
            self.iters
        )
    }
}

/// Render a bytes/second figure with a binary-prefix unit.
pub fn fmt_bytes_per_sec(bps: f64) -> String {
    if bps >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} GiB/s", bps / (1024.0 * 1024.0 * 1024.0))
    } else if bps >= 1024.0 * 1024.0 {
        format!("{:.2} MiB/s", bps / (1024.0 * 1024.0))
    } else if bps >= 1024.0 {
        format!("{:.2} KiB/s", bps / 1024.0)
    } else {
        format!("{bps:.0} B/s")
    }
}

/// Fixed-duration micro-benchmark runner.
pub struct BenchRunner {
    /// Untimed warmup duration.
    pub warmup: Duration,
    /// Timed measurement duration.
    pub measure: Duration,
    /// Iteration cap within the measurement window.
    pub max_iters: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            max_iters: 10_000,
        }
    }
}

impl BenchRunner {
    /// Shorter windows for expensive benchmarks.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            max_iters: 2_000,
        }
    }

    /// Run `f` repeatedly; returns robust stats. `f` should return some value
    /// so the optimizer cannot elide the work (use `std::hint::black_box`).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        let wend = Instant::now() + self.warmup;
        while Instant::now() < wend {
            f();
        }
        let mut samples = Vec::new();
        let mend = Instant::now() + self.measure;
        while Instant::now() < mend && samples.len() < self.max_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        if samples.is_empty() {
            // f is slower than the measurement budget: take one sample.
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        BenchStats {
            name: name.to_string(),
            iters: n,
            mean_ns: samples.iter().sum::<f64>() / n as f64,
            p50_ns: samples[n / 2],
            p95_ns: samples[(n as f64 * 0.95) as usize % n],
            min_ns: samples[0],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_percentiles() {
        let r = BenchRunner {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            max_iters: 500,
        };
        let mut acc = 0u64;
        let stats = r.run("noop", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(stats.iters > 0);
        assert!(stats.min_ns <= stats.p50_ns);
        assert!(stats.p50_ns <= stats.p95_ns);
    }

    #[test]
    fn report_formats() {
        let s = BenchStats {
            name: "x".into(),
            iters: 3,
            mean_ns: 1.5e6,
            p50_ns: 1.4e6,
            p95_ns: 2.0e6,
            min_ns: 9.0e5,
        };
        assert!(s.report().contains("ms"));
        // 1 MiB in 1.5 ms ≈ 666 MiB/s
        let bps = s.bytes_per_sec(1 << 20);
        assert!((bps / (1024.0 * 1024.0) - 666.0).abs() < 10.0, "{bps}");
        assert!(s.throughput_report(1 << 20).contains("MiB/s"));
        assert!(fmt_bytes_per_sec(2.0 * 1024.0 * 1024.0 * 1024.0).contains("GiB/s"));
        assert!(fmt_bytes_per_sec(10.0).contains("B/s"));
    }
}
