//! Minimal JSON parser/serializer (in-tree substrate: no serde offline).
//!
//! Covers the full JSON grammar; numbers parse to f64 with an i64 fast path
//! so manifest shapes round-trip exactly. Used for artifacts/manifest.json,
//! golden test vectors, metrics logs, and experiment outputs.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (f64 storage, i64-exact fast path).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — deterministic serialization).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with a byte position.
#[derive(Debug)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset in the input.
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    /// Object field lookup (None on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Borrow as a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Read as f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Read as usize (truncating).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Borrow as an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Borrow as an object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Read as bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Vec<usize> from a numeric array (shapes).
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|x| x.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    /// Vec<f32> from a numeric array (golden data).
    pub fn f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|x| x.as_f64().map(|v| v as f32))
            .collect::<Option<Vec<_>>>()
    }

    // -- builders ----------------------------------------------------------

    /// Object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Numeric array from f32s.
    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Numeric array from f64s.
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Numeric array from usizes.
    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // -- serialization -----------------------------------------------------

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)),
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
            // python json.dump writes -Infinity for -inf
            if self.b[self.i..].starts_with(b"Infinity") {
                self.i += 8;
                return Ok(Json::Num(f64::NEG_INFINITY));
            }
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null, "e": {}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().f32_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn parse_nested_arrays() {
        let v = Json::parse("[[1,2],[3,4]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[1].usize_vec().unwrap(), vec![3, 4]);
    }

    #[test]
    fn integers_roundtrip_exactly() {
        let v = Json::Arr(vec![Json::Num(1048576.0), Json::Num(-7.0)]);
        assert_eq!(v.to_string(), "[1048576,-7]");
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn python_special_floats() {
        // python json.dump emits NaN / Infinity for non-finite floats
        let v = Json::parse("[NaN, Infinity, -Infinity]").unwrap();
        let a = v.as_arr().unwrap();
        assert!(a[0].as_f64().unwrap().is_nan());
        assert_eq!(a[1].as_f64().unwrap(), f64::INFINITY);
        assert_eq!(a[2].as_f64().unwrap(), f64::NEG_INFINITY);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""é\t\"""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é\t\"");
    }
}
