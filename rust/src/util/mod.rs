//! In-tree substrates: JSON, PRNG, CLI, TOML-subset config parsing,
//! property-test helpers, and timing utilities (offline build — see
//! Cargo.toml).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod timer;
pub mod tomlcfg;
