//! In-tree substrates: JSON, PRNG, CLI, TOML-subset config parsing,
//! property-test helpers, and timing utilities (offline build — see
//! Cargo.toml).

/// Tiny CLI argument parser.
pub mod cli;
/// Minimal JSON parser/serializer.
pub mod json;
/// Property-test helpers.
pub mod prop;
/// Deterministic PRNG (splitmix-based).
pub mod rng;
/// Timing + micro-benchmark harness.
pub mod timer;
/// Minimal TOML-subset parser.
pub mod tomlcfg;
