//! TOML-subset parser (in-tree substrate for the `toml` crate).
//!
//! Supports what run configs need: `[section]` and `[section.sub]` headers,
//! `key = value` with strings, integers, floats, booleans, and flat arrays,
//! plus `#` comments. Values land in a flat `section.key -> TomlValue` map.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// A quoted string.
    Str(String),
    /// An integer.
    Int(i64),
    /// A float.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// An inline array.
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    /// Borrow as a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Read as i64 (integers only).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }

    /// Read as f64 (ints widen).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    /// Read as bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse failure with a line number.
#[derive(Debug)]
pub struct TomlError {
    /// 1-based line of the failure.
    pub line: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// A parsed TOML-subset document.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    /// Flattened `section.key` → value map.
    pub values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    /// Parse a TOML-subset document into a flat key map.
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(h) = line.strip_prefix('[') {
                let h = h.strip_suffix(']').ok_or_else(|| TomlError {
                    line: ln + 1,
                    msg: "unterminated section header".into(),
                })?;
                section = h.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| TomlError {
                line: ln + 1,
                msg: format!("expected key = value, got {line:?}"),
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = parse_value(v.trim()).map_err(|msg| TomlError { line: ln + 1, msg })?;
            doc.values.insert(key, val);
        }
        Ok(doc)
    }

    /// Look up a dotted `section.key`.
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    /// String at `key`, or `default`.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(TomlValue::as_str)
            .unwrap_or(default)
            .to_string()
    }

    /// i64 at `key`, or `default`.
    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(TomlValue::as_i64).unwrap_or(default)
    }

    /// usize at `key`, or `default`.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.i64_or(key, default as i64) as usize
    }

    /// f64 at `key`, or `default`.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(TomlValue::as_f64).unwrap_or(default)
    }

    /// bool at `key`, or `default`.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(TomlValue::as_bool).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // honor '#' outside of quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    if let Ok(x) = s.parse::<i64>() {
        return Ok(TomlValue::Int(x));
    }
    if let Ok(x) = s.parse::<f64>() {
        return Ok(TomlValue::Float(x));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config() {
        let doc = TomlDoc::parse(
            r#"
# run config
name = "demo"
[model]
kind = "tlm_tiny"   # inline comment
[optimizer]
lr = 1e-3
steps = 500
use_shampoo = true
bits = 4
buckets = [64, 128]
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "demo");
        assert_eq!(doc.str_or("model.kind", ""), "tlm_tiny");
        assert_eq!(doc.f64_or("optimizer.lr", 0.0), 1e-3);
        assert_eq!(doc.usize_or("optimizer.steps", 0), 500);
        assert!(doc.bool_or("optimizer.use_shampoo", false));
        match doc.get("optimizer.buckets").unwrap() {
            TomlValue::Arr(v) => assert_eq!(v.len(), 2),
            _ => panic!(),
        }
    }

    #[test]
    fn hash_inside_string() {
        let doc = TomlDoc::parse("tag = \"a#b\"").unwrap();
        assert_eq!(doc.str_or("tag", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = TomlDoc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = TomlDoc::parse("[sec\nx=1").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn defaults() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.usize_or("missing", 3), 3);
        assert_eq!(doc.str_or("missing", "d"), "d");
    }
}
