//! Synthetic data pipelines (DESIGN.md §Substitutions):
//!  * `vision` — class-conditional Gaussian-mixture features with a fixed
//!    random nonlinear map (stands in for CIFAR-100 / Tiny-ImageNet);
//!  * `corpus` — bigram-Markov token stream with Zipfian marginals (stands
//!    in for OpenWebText / C4).
//!
//! Both are deterministic given a seed, cheaply stream batches from a
//! background thread (`Prefetcher`), and carry a held-out split so test
//! accuracy / validation loss are measured on unseen data.

/// Bigram-Markov token stream (LM corpus stand-in).
pub mod corpus;
/// Gaussian-mixture classification features (vision stand-in).
pub mod vision;

use std::sync::mpsc;
use std::thread;

/// A training batch crossing into the model step artifact.
#[derive(Debug, Clone)]
pub enum Batch {
    /// Classification batch: features + integer labels.
    Vision {
        /// Row-major features, `batch × dim`.
        x: Vec<f32>,
        /// Class labels, `batch` long.
        y: Vec<i32>,
        /// Samples in the batch.
        batch: usize,
        /// Feature dimension.
        dim: usize,
    },
    /// LM batch: token windows (inputs + next-token targets).
    Tokens {
        /// Flat tokens, `batch × (seq+1)`.
        tokens: Vec<i32>,
        /// Sequences in the batch.
        batch: usize,
        /// Window length including the shifted target position.
        seq_plus1: usize,
    },
}

/// Background-thread batch prefetcher: the data pipeline never stalls the
/// training loop (L3 owns the event loop; std::thread + bounded channel
/// provide the backpressure).
pub struct Prefetcher {
    rx: mpsc::Receiver<Batch>,
    _handle: thread::JoinHandle<()>,
}

impl Prefetcher {
    /// Start a generator thread producing batches into a bounded channel of
    /// `depth` (backpressure: the generator blocks when the queue is full).
    pub fn spawn<F>(depth: usize, mut gen: F) -> Self
    where
        F: FnMut() -> Batch + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel(depth);
        let handle = thread::spawn(move || {
            loop {
                let b = gen();
                if tx.send(b).is_err() {
                    break; // consumer dropped
                }
            }
        });
        Prefetcher { rx, _handle: handle }
    }

    /// Take the next batch (blocks if the generator is behind).
    pub fn next(&self) -> Batch {
        self.rx.recv().expect("prefetcher thread died")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetcher_streams_batches() {
        let mut i = 0u64;
        let pf = Prefetcher::spawn(2, move || {
            i += 1;
            Batch::Vision { x: vec![i as f32], y: vec![0], batch: 1, dim: 1 }
        });
        let mut seen = Vec::new();
        for _ in 0..5 {
            if let Batch::Vision { x, .. } = pf.next() {
                seen.push(x[0]);
            }
        }
        // strictly increasing: batches arrive in generation order
        assert!(seen.windows(2).all(|w| w[1] > w[0]), "{seen:?}");
    }
}
