//! Synthetic data pipelines (DESIGN.md §Substitutions):
//!  * `vision` — class-conditional Gaussian-mixture features with a fixed
//!    random nonlinear map (stands in for CIFAR-100 / Tiny-ImageNet);
//!  * `corpus` — bigram-Markov token stream with Zipfian marginals (stands
//!    in for OpenWebText / C4).
//!
//! Both are deterministic given a seed, cheaply stream batches from a
//! background thread (`Prefetcher`), and carry a held-out split so test
//! accuracy / validation loss are measured on unseen data.

pub mod corpus;
pub mod vision;

use std::sync::mpsc;
use std::thread;

/// A training batch crossing into the model step artifact.
#[derive(Debug, Clone)]
pub enum Batch {
    /// (features [batch*dim], labels [batch])
    Vision { x: Vec<f32>, y: Vec<i32>, batch: usize, dim: usize },
    /// tokens [batch * (seq+1)]
    Tokens { tokens: Vec<i32>, batch: usize, seq_plus1: usize },
}

/// Background-thread batch prefetcher: the data pipeline never stalls the
/// training loop (L3 owns the event loop; std::thread + bounded channel
/// provide the backpressure).
pub struct Prefetcher {
    rx: mpsc::Receiver<Batch>,
    _handle: thread::JoinHandle<()>,
}

impl Prefetcher {
    pub fn spawn<F>(depth: usize, mut gen: F) -> Self
    where
        F: FnMut() -> Batch + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel(depth);
        let handle = thread::spawn(move || {
            loop {
                let b = gen();
                if tx.send(b).is_err() {
                    break; // consumer dropped
                }
            }
        });
        Prefetcher { rx, _handle: handle }
    }

    pub fn next(&self) -> Batch {
        self.rx.recv().expect("prefetcher thread died")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetcher_streams_batches() {
        let mut i = 0u64;
        let pf = Prefetcher::spawn(2, move || {
            i += 1;
            Batch::Vision { x: vec![i as f32], y: vec![0], batch: 1, dim: 1 }
        });
        let mut seen = Vec::new();
        for _ in 0..5 {
            if let Batch::Vision { x, .. } = pf.next() {
                seen.push(x[0]);
            }
        }
        // strictly increasing: batches arrive in generation order
        assert!(seen.windows(2).all(|w| w[1] > w[0]), "{seen:?}");
    }
}
