//! Synthetic image-classification dataset (CIFAR-100 stand-in).
//!
//! Class-conditional Gaussian mixture passed through a fixed random
//! nonlinear map: each class c has a latent mean μ_c; a sample is
//! tanh(W·(μ_c + σ·ε)) with W a fixed random projection. Learnable by an
//! MLP (accuracy well above chance), non-trivially hard (class overlap via
//! σ), and deterministic given the seed. Train/test splits use disjoint
//! noise streams.

use crate::util::rng::Rng;

/// Class-conditional Gaussian-mixture features behind a fixed random
/// nonlinear map (see the module docs).
pub struct VisionDataset {
    /// Feature dimension of a sample.
    pub dim: usize,
    /// Number of classes.
    pub classes: usize,
    means: Vec<f32>,     // classes × latent
    proj: Vec<f32>,      // latent × dim (fixed random map)
    latent: usize,
    noise: f32,
    seed: u64,
}

impl VisionDataset {
    /// Build the dataset's fixed class means + projection from `seed`.
    pub fn new(dim: usize, classes: usize, seed: u64) -> Self {
        let latent = 32;
        let mut rng = Rng::new(seed ^ 0xDA7A_0001);
        let means: Vec<f32> = (0..classes * latent)
            .map(|_| rng.normal_f32() * 1.6)
            .collect();
        let proj: Vec<f32> = (0..latent * dim)
            .map(|_| rng.normal_f32() / (latent as f32).sqrt())
            .collect();
        Self { dim, classes, means, proj, latent, noise: 1.0, seed }
    }

    /// Sample a batch from the given split ("train" streams are endless;
    /// "test" uses a disjoint seed space and is reproducible per index).
    pub fn batch(&self, batch: usize, split: Split, index: u64) -> (Vec<f32>, Vec<i32>) {
        let tag = match split {
            Split::Train => 0x7EA1_0000u64,
            Split::Test => 0x7E57_0000u64,
        };
        let mut rng = Rng::new(self.seed ^ tag ^ index.wrapping_mul(0x9E37_79B9));
        let mut x = Vec::with_capacity(batch * self.dim);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let c = rng.below(self.classes);
            y.push(c as i32);
            // latent = μ_c + σ·ε
            let mut z = vec![0.0f32; self.latent];
            for (k, zk) in z.iter_mut().enumerate() {
                *zk = self.means[c * self.latent + k] + self.noise * rng.normal_f32();
            }
            // x = tanh(projᵀ z)
            for j in 0..self.dim {
                let mut acc = 0.0f32;
                for k in 0..self.latent {
                    acc += self.proj[k * self.dim + j] * z[k];
                }
                x.push(acc.tanh());
            }
        }
        (x, y)
    }
}

/// Which disjoint noise stream a batch is drawn from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    /// Endless training stream.
    Train,
    /// Held-out stream (reproducible per index).
    Test,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_index() {
        let ds = VisionDataset::new(64, 10, 7);
        let (x1, y1) = ds.batch(8, Split::Train, 3);
        let (x2, y2) = ds.batch(8, Split::Train, 3);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        let (x3, _) = ds.batch(8, Split::Train, 4);
        assert_ne!(x1, x3);
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let ds = VisionDataset::new(64, 10, 7);
        let (tr, _) = ds.batch(8, Split::Train, 0);
        let (te, _) = ds.batch(8, Split::Test, 0);
        assert_ne!(tr, te);
    }

    #[test]
    fn features_bounded_and_labels_valid() {
        let ds = VisionDataset::new(128, 100, 1);
        let (x, y) = ds.batch(64, Split::Train, 0);
        assert!(x.iter().all(|&v| (-1.0..=1.0).contains(&v)));
        assert!(y.iter().all(|&c| (0..100).contains(&c)));
        assert_eq!(x.len(), 64 * 128);
    }

    #[test]
    fn classes_are_linearly_separable_ish() {
        // nearest-class-mean classifier in feature space must beat chance by
        // a wide margin — guarantees the dataset is learnable
        let ds = VisionDataset::new(64, 10, 2);
        let per_class = 30;
        // estimate class means from train
        let mut means = vec![0.0f32; 10 * 64];
        let mut counts = [0usize; 10];
        for idx in 0..40 {
            let (x, y) = ds.batch(16, Split::Train, idx);
            for (b, &c) in y.iter().enumerate() {
                counts[c as usize] += 1;
                for j in 0..64 {
                    means[c as usize * 64 + j] += x[b * 64 + j];
                }
            }
        }
        for c in 0..10 {
            for j in 0..64 {
                means[c * 64 + j] /= counts[c].max(1) as f32;
            }
        }
        // classify held-out
        let mut correct = 0;
        let mut total = 0;
        for idx in 0..per_class {
            let (x, y) = ds.batch(16, Split::Test, idx);
            for (b, &cy) in y.iter().enumerate() {
                let mut best = (f32::INFINITY, 0usize);
                for c in 0..10 {
                    let d: f32 = (0..64)
                        .map(|j| {
                            let diff = x[b * 64 + j] - means[c * 64 + j];
                            diff * diff
                        })
                        .sum();
                    if d < best.0 {
                        best = (d, c);
                    }
                }
                if best.1 == cy as usize {
                    correct += 1;
                }
                total += 1;
            }
        }
        let acc = correct as f64 / total as f64;
        assert!(acc > 0.5, "nearest-mean accuracy {acc}");
    }
}
