//! Synthetic language-modeling corpus (OpenWebText / C4 stand-in).
//!
//! A fixed random bigram Markov chain with Zipfian stationary-ish
//! marginals: each token's successor distribution mixes a Zipf unigram
//! prior with a sparse token-specific component. A transformer LM trained
//! on this reduces loss from ln(V) toward the chain's conditional entropy —
//! giving real, interpretable loss curves (Figure 10 shape).

use crate::util::rng::Rng;

/// Fixed random bigram Markov chain with Zipfian marginals (see the
/// module docs).
pub struct BigramCorpus {
    /// Vocabulary size.
    pub vocab: usize,
    /// per-token successor CDFs, row-major vocab × vocab
    cdf: Vec<f64>,
    seed: u64,
    /// conditional entropy of the chain in nats (the loss floor)
    pub entropy: f64,
}

impl BigramCorpus {
    /// Build the chain's successor CDFs (and its entropy floor) from `seed`.
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xC0_4055);
        // Zipf unigram prior
        let zipf: Vec<f64> = (0..vocab).map(|i| 1.0 / (i as f64 + 2.7)).collect();
        let zsum: f64 = zipf.iter().sum();
        let mut cdf = vec![0.0f64; vocab * vocab];
        let mut entropy_acc = 0.0;
        let mut stat_weight = 0.0;
        for t in 0..vocab {
            // successor distribution: 0.5·zipf + 0.5·(8 random heavy tokens)
            let mut probs: Vec<f64> = zipf.iter().map(|&z| 0.5 * z / zsum).collect();
            for _ in 0..8 {
                let j = rng.below(vocab);
                probs[j] += 0.5 / 8.0;
            }
            let mut acc = 0.0;
            let mut h = 0.0;
            for (j, &p) in probs.iter().enumerate() {
                acc += p;
                cdf[t * vocab + j] = acc;
                if p > 0.0 {
                    h -= p * p.ln();
                }
            }
            // weight rows by the unigram prior as a stationary proxy
            let w = zipf[t] / zsum;
            entropy_acc += w * h;
            stat_weight += w;
        }
        BigramCorpus {
            vocab,
            cdf,
            seed,
            entropy: entropy_acc / stat_weight,
        }
    }

    /// Generate a (batch, seq+1) token block; split/index seed the stream.
    pub fn batch(&self, batch: usize, seq_plus1: usize, test: bool, index: u64) -> Vec<i32> {
        let tag = if test { 0x7E57u64 } else { 0x7EA1u64 };
        let mut rng = Rng::new(self.seed ^ (tag << 32) ^ index.wrapping_mul(0x9E37_79B9));
        let mut out = Vec::with_capacity(batch * seq_plus1);
        for _ in 0..batch {
            let mut t = rng.below(self.vocab);
            out.push(t as i32);
            for _ in 1..seq_plus1 {
                let row = &self.cdf[t * self.vocab..(t + 1) * self.vocab];
                t = rng.weighted(row);
                out.push(t as i32);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let c = BigramCorpus::new(64, 3);
        assert_eq!(c.batch(4, 17, false, 5), c.batch(4, 17, false, 5));
        assert_ne!(c.batch(4, 17, false, 5), c.batch(4, 17, false, 6));
        assert_ne!(c.batch(4, 17, false, 5), c.batch(4, 17, true, 5));
    }

    #[test]
    fn tokens_in_range() {
        let c = BigramCorpus::new(256, 1);
        let toks = c.batch(8, 65, false, 0);
        assert_eq!(toks.len(), 8 * 65);
        assert!(toks.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn entropy_below_uniform() {
        let c = BigramCorpus::new(256, 2);
        assert!(c.entropy < (256f64).ln() * 0.9, "{}", c.entropy);
        assert!(c.entropy > 1.0);
    }

    #[test]
    fn bigram_statistics_are_learnable() {
        // empirical successor distribution of token 0 must be far from
        // uniform (a bigram model can beat the unigram baseline)
        let c = BigramCorpus::new(32, 4);
        let toks = c.batch(64, 129, false, 0);
        let mut counts = vec![0usize; 32];
        let mut total = 0usize;
        for row in toks.chunks(129) {
            for w in row.windows(2) {
                if w[0] == 0 {
                    counts[w[1] as usize] += 1;
                    total += 1;
                }
            }
        }
        if total > 50 {
            let maxp = counts.iter().cloned().max().unwrap() as f64 / total as f64;
            assert!(maxp > 2.0 / 32.0, "max successor prob {maxp}");
        }
    }
}
