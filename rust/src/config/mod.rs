//! Run configuration: one TOML file describes a full training run
//! (model, data, first-order optimizer, second-order preconditioner,
//! quantization, schedule). See configs/ for shipped presets.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::quant::{parse_policy_entry, BufferRole, CodecPolicy, CodecSpec, Mapping};
use crate::util::tomlcfg::TomlDoc;

/// First-order optimizer family F (eq. 1 + the Appendix H comparison arms).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FirstOrderKind {
    /// SGD with momentum.
    Sgdm,
    /// AdamW (decoupled weight decay).
    AdamW,
    /// NAdamW (Nesterov momentum inside AdamW).
    NAdamW,
    /// Adagrad.
    Adagrad,
    /// Schedule-free SGD (Defazio et al. 2024).
    SgdScheduleFree,
    /// Schedule-free AdamW (Defazio et al. 2024).
    AdamWScheduleFree,
    /// M-FAC (Frantar et al. 2021), the Table 11 memory comparison arm.
    MFac,
}

impl FirstOrderKind {
    /// Parse a config/CLI optimizer name (case-insensitive).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "sgdm" | "sgd" => Self::Sgdm,
            "adamw" => Self::AdamW,
            "nadamw" => Self::NAdamW,
            "adagrad" => Self::Adagrad,
            "sgdschedulefree" | "sgd_schedule_free" => Self::SgdScheduleFree,
            "adamwschedulefree" | "adamw_schedule_free" => Self::AdamWScheduleFree,
            "mfac" | "m-fac" => Self::MFac,
            other => bail!("unknown first-order optimizer {other:?}"),
        })
    }

    /// Canonical display name (Table 2/4 row labels).
    pub fn name(&self) -> &'static str {
        match self {
            Self::Sgdm => "SGDM",
            Self::AdamW => "AdamW",
            Self::NAdamW => "NAdamW",
            Self::Adagrad => "Adagrad",
            Self::SgdScheduleFree => "SGDScheduleFree",
            Self::AdamWScheduleFree => "AdamWScheduleFree",
            Self::MFac => "M-FAC",
        }
    }
}

/// Second-order preconditioner family (Algorithm 3/5 + Appendix A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SecondOrderKind {
    /// No second-order preconditioning (pure F).
    None,
    /// Shampoo (GGᵀ/GᵀG statistics, −1/4 roots).
    Shampoo,
    /// CASPR (combined axis-sum preconditioning).
    Caspr,
    /// K-FAC (layer statistics, −1 exponent).
    KFac,
    /// AdaBK (layer statistics, −1/2 exponent).
    AdaBk,
}

impl SecondOrderKind {
    /// Parse a config/CLI preconditioner name (case-insensitive).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "none" | "" => Self::None,
            "shampoo" => Self::Shampoo,
            "caspr" => Self::Caspr,
            "kfac" | "k-fac" => Self::KFac,
            "adabk" | "ada_bk" => Self::AdaBk,
            other => bail!("unknown second-order optimizer {other:?}"),
        })
    }

    /// Canonical display name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Shampoo => "Shampoo",
            Self::Caspr => "CASPR",
            Self::KFac => "K-FAC",
            Self::AdaBk => "AdaBK",
        }
    }

    /// Inverse-root exponent denominator α: Â = (L + ρI)^{-1/α}.
    pub fn alpha(&self) -> u32 {
        match self {
            Self::KFac => 1,
            Self::AdaBk => 2,
            _ => 4,
        }
    }
}

/// Quantized-state policy for the second-order states.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantConfig {
    /// 32 = dense baseline (no quantization).
    pub bits: u32,
    /// Codebook mapping for quantized second-order states.
    pub mapping: Mapping,
    /// Quantize the eigenvector matrix (ours) vs the preconditioner (naive).
    pub quantize_eigen: bool,
    /// Björck rectification on (t1/t2 from the manifest defaults).
    pub rectify: bool,
    /// Matrices with fewer elements than this stay 32-bit (paper: 4096).
    pub min_quant_elems: usize,
}

impl Default for QuantConfig {
    fn default() -> Self {
        Self {
            bits: 4,
            mapping: Mapping::Linear2,
            quantize_eigen: true,
            rectify: true,
            min_quant_elems: 4096,
        }
    }
}

/// Second-order (`[shampoo]` / `[quant]`) section of a run config.
#[derive(Debug, Clone)]
pub struct SecondOrderConfig {
    /// Preconditioner family (Shampoo/CASPR/K-FAC/AdaBK, or `None`).
    pub kind: SecondOrderKind,
    /// Storage policy for the preconditioner states.
    pub quant: QuantConfig,
    /// Preconditioner update interval (T1).
    pub update_precond_every: usize,
    /// Inverse-root update interval (T2).
    pub update_invroot_every: usize,
    /// EMA decay β for preconditioners.
    pub beta: f32,
    /// Dampening ε.
    pub eps: f32,
    /// Max preconditioner order (blocks above are split).
    pub max_order: usize,
    /// Start preconditioning after this step (warmup on pure F).
    pub start_step: usize,
    /// Worker threads for the parallel block engine (per-block PU / PIRU /
    /// precondition fan-out). 1 = serial; results are bit-identical at any
    /// value. Defaults to `SHAMPOO4_PARALLELISM` when set, else 1.
    pub parallelism: usize,
    /// Spread per-block inverse-root (PIRU) work round-robin across the T2
    /// interval instead of batching every block on the T2-boundary step —
    /// same work per interval, no wall-clock spike.
    pub stagger_invroots: bool,
    /// Cross-step pipelining: PU/PIRU refreshes run asynchronously on the
    /// persistent worker pool and overlap subsequent model steps; the
    /// refreshed inverse roots are swapped in at a deterministic completion
    /// barrier (double-buffered per block, so `precondition` never reads a
    /// half-updated root). Preconditioning sees roots up to
    /// `pipeline_max_lag` steps stale — the same tolerance regime as
    /// `stagger_invroots`. Off by default (bit-identical to the serial
    /// engine).
    pub pipeline: bool,
    /// Bounded staleness for the pipelined engine: an in-flight refresh is
    /// force-completed after this many steps even if no new refresh is due.
    pub pipeline_max_lag: usize,
    /// Adaptive lag: when every background job of the in-flight refresh has
    /// already reported (the pool went idle), swap the results in at the
    /// next step's barrier instead of waiting out the full lag bound —
    /// fresher roots at zero extra stall. Completion steps then depend on
    /// pool timing, so adaptive runs are *reproducible in quality* but not
    /// bit-reproducible across machines; off by default.
    pub pipeline_adaptive: bool,
    /// Shard the second-order blocks across this many shard workers, each
    /// owning its own `Backend` instance and its own slice of block states
    /// (`[shard]` `count` / `--shards`). Blocks are assigned round-robin
    /// (`block_idx % shards`) and refresh requests/replies travel as
    /// codec-encoded bytes, so sharded runs are bit-identical to
    /// single-process runs at any shard count. 1 = no sharding (the
    /// in-process engine runs unchanged).
    pub shards: usize,
}

/// Default worker count: the `SHAMPOO4_PARALLELISM` env var when set (CI uses
/// it to force the threaded path through every default-config run), else 1.
pub fn default_parallelism() -> usize {
    std::env::var("SHAMPOO4_PARALLELISM")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&p| p >= 1)
        .unwrap_or(1)
}

impl Default for SecondOrderConfig {
    fn default() -> Self {
        Self {
            kind: SecondOrderKind::Shampoo,
            quant: QuantConfig::default(),
            update_precond_every: 100,
            update_invroot_every: 500,
            beta: 0.95,
            eps: 1e-4,
            max_order: 128,
            start_step: 1,
            parallelism: default_parallelism(),
            stagger_invroots: false,
            pipeline: false,
            pipeline_max_lag: 4,
            pipeline_adaptive: false,
            shards: 1,
        }
    }
}

/// First-order (`[optimizer]` / `[first_order]`) section of a run config.
#[derive(Debug, Clone)]
pub struct FirstOrderConfig {
    /// Optimizer family F.
    pub kind: FirstOrderKind,
    /// Base learning rate (scaled by the schedule).
    pub lr: f32,
    /// Weight-decay coefficient.
    pub weight_decay: f32,
    /// Momentum (SGDM / M-FAC).
    pub momentum: f32,
    /// Adam β₁.
    pub beta1: f32,
    /// Adam β₂.
    pub beta2: f32,
    /// Adam ε.
    pub eps: f32,
    /// M-FAC gradient history length.
    pub mfac_m: usize,
    /// Storage bitwidth for first-order moment buffers (`first_order.bits`):
    /// 32 = fp32 (default), 16 = bf16, 2–8 = block-wise quantized states
    /// (Dettmers et al. 2021 / Li et al. 2023 — the Table 13 baselines).
    /// This is the legacy single knob: per-buffer `[quant.policy]` entries
    /// override it role by role (see [`RunConfig::quant_policy`]).
    pub bits: u32,
    /// Codebook mapping for quantized moment storage (`first_order.mapping`).
    pub mapping: Mapping,
}

impl Default for FirstOrderConfig {
    fn default() -> Self {
        Self {
            kind: FirstOrderKind::AdamW,
            lr: 1e-3,
            weight_decay: 0.05,
            momentum: 0.9,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            mfac_m: 8,
            bits: 32,
            mapping: Mapping::Dt,
        }
    }
}

/// Learning-rate schedule (Appendix G uses multi-step for CNNs, cosine for
/// transformers, plus the schedule-free arm).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// Flat learning rate.
    Constant,
    /// Linear warmup, then a cosine decay to ~0.
    Cosine {
        /// Warmup steps.
        warmup: usize,
    },
    /// Linear warmup, then step decays by `gamma`.
    MultiStep {
        /// Warmup steps.
        warmup: usize,
        /// Fraction of total steps between decays.
        decay_every_frac: f32,
        /// Multiplicative decay per phase.
        gamma: f32,
    },
}

/// One full training-run configuration (a TOML file / CLI overrides).
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Run name (output directory, bench row label).
    pub name: String,
    /// Model key in the backend manifest (`mlp_base`, `tlm_tiny`, ...).
    pub model: String,
    /// Total optimizer steps.
    pub steps: usize,
    /// RNG seed for init + data.
    pub seed: u64,
    /// First-order optimizer section.
    pub first: FirstOrderConfig,
    /// Second-order preconditioner section.
    pub second: SecondOrderConfig,
    /// Learning-rate schedule.
    pub schedule: Schedule,
    /// Evaluate every N steps (0 = only at the end).
    pub eval_every: usize,
    /// Held-out batches per evaluation (0 = skip final eval).
    pub eval_batches: usize,
    /// Record the training loss every N steps.
    pub log_every: usize,
    /// Directory with AOT artifacts (PJRT backend).
    pub artifact_dir: String,
    /// Execution backend: "host" (pure Rust, hermetic), "pjrt" (AOT
    /// artifacts, requires --features pjrt), or "auto" (pjrt when compiled
    /// artifacts exist, host otherwise).
    pub backend: String,
    /// Record dynamic quantization error against a 32-bit shadow
    /// preconditioner (Figures 7/8).
    pub shadow_quant_error: bool,
    /// Per-buffer codec policy entries (`[quant.policy]` in TOML,
    /// `--quant-policy` on the CLI; later entries override earlier ones).
    /// Roles without an entry fall back to the legacy single knobs
    /// (`first_order.bits`/`.mapping`, `quant.bits`/`.mapping`), so an
    /// empty policy reproduces pre-policy behavior exactly.
    pub quant_policy: Vec<(BufferRole, CodecSpec)>,
    /// Save the end-of-run checkpoint as an incremental delta against the
    /// checkpoint the run resumed from (`run.checkpoint_delta` /
    /// `--checkpoint-delta`): buffers whose codec bytes are unchanged are
    /// recorded in the manifest but not rewritten. Ignored when the run
    /// did not resume from a v1 checkpoint.
    pub checkpoint_delta: bool,
    /// Chunk size in bytes for streaming checkpoint writes
    /// (`run.checkpoint_chunk_bytes` / `--checkpoint-chunk-bytes`): large
    /// frames are produced and written through a buffer of roughly this
    /// size instead of staging the whole frame. Must be > 0.
    pub checkpoint_chunk_bytes: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            name: "run".into(),
            model: "mlp_base".into(),
            steps: 200,
            seed: 0,
            first: FirstOrderConfig::default(),
            second: SecondOrderConfig::default(),
            schedule: Schedule::Cosine { warmup: 10 },
            eval_every: 100,
            eval_batches: 8,
            log_every: 10,
            artifact_dir: "artifacts".into(),
            backend: "auto".into(),
            shadow_quant_error: false,
            quant_policy: Vec::new(),
            checkpoint_delta: false,
            checkpoint_chunk_bytes: 1 << 20,
        }
    }
}

impl RunConfig {
    /// Parse a TOML document (unknown keys are ignored; missing keys take
    /// the defaults) and validate the result.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut cfg = RunConfig::default();
        cfg.name = doc.str_or("name", &cfg.name);
        cfg.model = doc.str_or("model.kind", &cfg.model);
        cfg.steps = doc.usize_or("run.steps", cfg.steps);
        cfg.seed = doc.i64_or("run.seed", cfg.seed as i64) as u64;
        cfg.eval_every = doc.usize_or("run.eval_every", cfg.eval_every);
        cfg.eval_batches = doc.usize_or("run.eval_batches", cfg.eval_batches);
        cfg.log_every = doc.usize_or("run.log_every", cfg.log_every);
        cfg.artifact_dir = doc.str_or("run.artifact_dir", &cfg.artifact_dir);
        cfg.backend = doc.str_or("run.backend", &cfg.backend);
        cfg.shadow_quant_error = doc.bool_or("run.shadow_quant_error", false);
        cfg.checkpoint_delta = doc.bool_or("run.checkpoint_delta", cfg.checkpoint_delta);
        cfg.checkpoint_chunk_bytes =
            doc.usize_or("run.checkpoint_chunk_bytes", cfg.checkpoint_chunk_bytes);

        let f = &mut cfg.first;
        f.kind = FirstOrderKind::parse(&doc.str_or("optimizer.kind", "adamw"))?;
        f.lr = doc.f64_or("optimizer.lr", f.lr as f64) as f32;
        f.weight_decay = doc.f64_or("optimizer.weight_decay", f.weight_decay as f64) as f32;
        f.momentum = doc.f64_or("optimizer.momentum", f.momentum as f64) as f32;
        f.beta1 = doc.f64_or("optimizer.beta1", f.beta1 as f64) as f32;
        f.beta2 = doc.f64_or("optimizer.beta2", f.beta2 as f64) as f32;
        f.eps = doc.f64_or("optimizer.eps", f.eps as f64) as f32;
        f.mfac_m = doc.usize_or("optimizer.mfac_m", f.mfac_m);
        f.bits = doc.usize_or("first_order.bits", f.bits as usize) as u32;
        f.mapping = Mapping::parse_named(&doc.str_or("first_order.mapping", f.mapping.name()))
            .context("first_order.mapping")?;

        let s = &mut cfg.second;
        s.kind = SecondOrderKind::parse(&doc.str_or("shampoo.kind", "shampoo"))?;
        if !doc.bool_or("shampoo.enabled", true) {
            s.kind = SecondOrderKind::None;
        }
        s.update_precond_every = doc.usize_or("shampoo.t1", s.update_precond_every);
        s.update_invroot_every = doc.usize_or("shampoo.t2", s.update_invroot_every);
        s.beta = doc.f64_or("shampoo.beta", s.beta as f64) as f32;
        s.eps = doc.f64_or("shampoo.eps", s.eps as f64) as f32;
        s.max_order = doc.usize_or("shampoo.max_order", s.max_order);
        s.start_step = doc.usize_or("shampoo.start_step", s.start_step);
        s.parallelism = doc.usize_or("shampoo.parallelism", s.parallelism).max(1);
        s.stagger_invroots = doc.bool_or("shampoo.stagger_invroots", s.stagger_invroots);
        s.pipeline = doc.bool_or("shampoo.pipeline", s.pipeline);
        s.pipeline_max_lag =
            doc.usize_or("shampoo.pipeline_max_lag", s.pipeline_max_lag).max(1);

        s.pipeline_adaptive = doc.bool_or("shampoo.pipeline_adaptive", s.pipeline_adaptive);
        s.shards = doc.usize_or("shard.count", s.shards).max(1);

        let q = &mut s.quant;
        q.bits = doc.usize_or("quant.bits", q.bits as usize) as u32;
        q.mapping = Mapping::parse_named(&doc.str_or("quant.mapping", "linear2"))
            .context("quant.mapping")?;
        q.quantize_eigen = doc.bool_or("quant.quantize_eigen", q.quantize_eigen);
        q.rectify = doc.bool_or("quant.rectify", q.rectify);
        q.min_quant_elems = doc.usize_or("quant.min_quant_elems", q.min_quant_elems);

        // [quant.policy]: per-buffer codec entries (role = "codec-name")
        let prefix = "quant.policy.";
        let (first_map, second_map) = (cfg.first.mapping, cfg.second.quant.mapping);
        for (key, val) in doc.values.iter().filter(|(k, _)| k.starts_with(prefix)) {
            let spec = val.as_str().ok_or_else(|| {
                anyhow!("{key} must be a quoted codec name (e.g. \"q4-linear2\")")
            })?;
            cfg.quant_policy.push(
                parse_policy_entry(&key[prefix.len()..], spec, first_map, second_map)
                    .with_context(|| key.clone())?,
            );
        }

        cfg.schedule = match doc.str_or("schedule.kind", "cosine").as_str() {
            "constant" => Schedule::Constant,
            "cosine" => Schedule::Cosine { warmup: doc.usize_or("schedule.warmup", 10) },
            "multistep" => Schedule::MultiStep {
                warmup: doc.usize_or("schedule.warmup", 10),
                decay_every_frac: doc.f64_or("schedule.decay_every_frac", 0.3) as f32,
                gamma: doc.f64_or("schedule.gamma", 0.1) as f32,
            },
            other => bail!("unknown schedule {other:?}"),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The run's codec policy resolver: the `[quant.policy]`/`--quant-policy`
    /// entries plus the run seed (which seeds per-buffer stochastic-rounding
    /// streams). Built on demand so CLI overrides of entries *and* seed are
    /// both reflected.
    pub fn codec_policy(&self) -> CodecPolicy {
        CodecPolicy::new(self.quant_policy.clone(), self.seed)
    }

    /// The spec the second-order `role` resolves to under this config
    /// (policy entry, `eigen` fallback, then the `quant.bits` single knob).
    pub fn second_order_spec(&self, role: BufferRole) -> CodecSpec {
        self.codec_policy()
            .resolve(role, CodecSpec::plain(self.second.quant.bits, self.second.quant.mapping))
    }

    /// Reject storage policies no codec implements (checked again by
    /// `Trainer::new` so CLI overrides are validated too).
    pub fn validate(&self) -> Result<()> {
        if !matches!(self.first.bits, 2..=8 | 16 | 32) {
            bail!(
                "first_order.bits must be 2–8 (quantized), 16 (bf16), or 32 (fp32); got {}",
                self.first.bits
            );
        }
        // per-side validation subsumes the old flat quant.bits check: the
        // resolved spec is the policy entry when one exists, else the
        // quant.bits/quant.mapping single knob — so `[quant] bits = 8` with a
        // policy that covers both sides is VALID, and bits = 8 with no policy
        // still fails here (on the fallback spec)
        if self.second.kind != SecondOrderKind::None {
            for role in [BufferRole::LeftSide, BufferRole::RightSide] {
                let spec = self.second_order_spec(role);
                if !matches!(spec.bits, 3 | 4 | 16 | 32) {
                    bail!(
                        "second-order side {:?} resolves to codec {} (via [quant.policy] \
                         or the quant.bits knob): sides need 3 or 4 bits (quantized \
                         kernels) or 16/32 (dense)",
                        role.name(),
                        spec.name()
                    );
                }
                if spec.stochastic {
                    bail!(
                        "quant policy resolves second-order role {:?} to {}: stochastic \
                         rounding applies to first-order moment buffers only (the PU/PIRU \
                         artifacts quantize with nearest-rounding kernels)",
                        role.name(),
                        spec.name()
                    );
                }
            }
        }
        if self.checkpoint_chunk_bytes == 0 {
            bail!("run.checkpoint_chunk_bytes must be > 0");
        }
        if self.second.pipeline
            && self.second.kind != SecondOrderKind::None
            && self.shadow_quant_error
        {
            bail!(
                "shampoo.pipeline and run.shadow_quant_error are mutually exclusive: the \
                 shadow tracker mirrors PU synchronously, which the asynchronous pipeline \
                 cannot provide"
            );
        }
        Ok(())
    }

    /// [`RunConfig::from_toml_str`] on a file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml_str(&text)
    }

    /// LR multiplier at a step (the F's base lr × this).
    pub fn lr_at(&self, step: usize) -> f32 {
        match self.schedule {
            Schedule::Constant => 1.0,
            Schedule::Cosine { warmup } => {
                if step < warmup {
                    (step + 1) as f32 / warmup.max(1) as f32
                } else {
                    let t = (step - warmup) as f32
                        / (self.steps.saturating_sub(warmup)).max(1) as f32;
                    0.5 * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos())
                }
            }
            Schedule::MultiStep { warmup, decay_every_frac, gamma } => {
                if step < warmup {
                    (step + 1) as f32 / warmup.max(1) as f32
                } else {
                    let phase = (step as f32 / self.steps.max(1) as f32
                        / decay_every_frac) as usize;
                    gamma.powi(phase as i32)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::from_toml_str(
            r#"
name = "swin-like"
[model]
kind = "tlm_small"
[run]
steps = 400
seed = 3
[optimizer]
kind = "adamw"
lr = 0.001
weight_decay = 0.05
[shampoo]
kind = "shampoo"
t1 = 100
t2 = 500
beta = 0.95
[quant]
bits = 4
mapping = "linear2"
quantize_eigen = true
[schedule]
kind = "cosine"
warmup = 20
"#,
        )
        .unwrap();
        assert_eq!(cfg.model, "tlm_small");
        assert_eq!(cfg.steps, 400);
        assert_eq!(cfg.second.update_precond_every, 100);
        assert_eq!(cfg.second.quant.bits, 4);
        assert_eq!(cfg.first.kind, FirstOrderKind::AdamW);
        assert!(matches!(cfg.schedule, Schedule::Cosine { warmup: 20 }));
        // checkpoint knobs default off / 1 MiB
        assert!(!cfg.checkpoint_delta);
        assert_eq!(cfg.checkpoint_chunk_bytes, 1 << 20);
    }

    #[test]
    fn parses_checkpoint_knobs() {
        let cfg = RunConfig::from_toml_str(
            "[run]\ncheckpoint_delta = true\ncheckpoint_chunk_bytes = 4096\n",
        )
        .unwrap();
        assert!(cfg.checkpoint_delta);
        assert_eq!(cfg.checkpoint_chunk_bytes, 4096);
        cfg.validate().unwrap();

        let bad = RunConfig { checkpoint_chunk_bytes: 0, ..RunConfig::default() };
        let err = bad.validate().unwrap_err();
        assert!(format!("{err:#}").contains("checkpoint_chunk_bytes"));
    }

    #[test]
    fn parallel_engine_keys_parse() {
        let cfg = RunConfig::from_toml_str(
            "[shampoo]\nparallelism = 4\nstagger_invroots = true",
        )
        .unwrap();
        assert_eq!(cfg.second.parallelism, 4);
        assert!(cfg.second.stagger_invroots);
        // parallelism is clamped to >= 1
        let cfg = RunConfig::from_toml_str("[shampoo]\nparallelism = 0").unwrap();
        assert_eq!(cfg.second.parallelism, 1);
        assert!(!cfg.second.stagger_invroots);
    }

    #[test]
    fn pipeline_keys_parse() {
        let cfg = RunConfig::from_toml_str(
            "[shampoo]\npipeline = true\npipeline_max_lag = 7\nparallelism = 2",
        )
        .unwrap();
        assert!(cfg.second.pipeline);
        assert_eq!(cfg.second.pipeline_max_lag, 7);
        // defaults: off, lag 4; lag clamped to >= 1
        let d = RunConfig::default();
        assert!(!d.second.pipeline);
        assert_eq!(d.second.pipeline_max_lag, 4);
        let cfg = RunConfig::from_toml_str("[shampoo]\npipeline_max_lag = 0").unwrap();
        assert_eq!(cfg.second.pipeline_max_lag, 1);
        // pipeline + shadow tracker is rejected (shadow mirrors PU synchronously)
        assert!(RunConfig::from_toml_str(
            "[run]\nshadow_quant_error = true\n[shampoo]\npipeline = true"
        )
        .is_err());
        // ...but fine when no second-order optimizer runs
        assert!(RunConfig::from_toml_str(
            "[run]\nshadow_quant_error = true\n[shampoo]\nenabled = false\npipeline = true"
        )
        .is_ok());
    }

    #[test]
    fn first_order_codec_policy_parses() {
        let cfg =
            RunConfig::from_toml_str("[first_order]\nbits = 4\nmapping = \"dt\"").unwrap();
        assert_eq!(cfg.first.bits, 4);
        assert_eq!(cfg.first.mapping, Mapping::Dt);
        assert_eq!(RunConfig::default().first.bits, 32);
        assert!(RunConfig::from_toml_str("[first_order]\nbits = 12").is_err());
        assert!(RunConfig::from_toml_str("[first_order]\nmapping = \"bogus\"").is_err());
        // second-order 8-bit has no 16-entry kernel codebook...
        assert!(RunConfig::from_toml_str("[quant]\nbits = 8").is_err());
        // ...but is fine when the second-order arm is disabled
        assert!(
            RunConfig::from_toml_str("[shampoo]\nenabled = false\n[quant]\nbits = 8").is_ok()
        );
    }

    #[test]
    fn quant_policy_table_parses_and_resolves() {
        let cfg = RunConfig::from_toml_str(
            r#"
[first_order]
mapping = "dt"
[quant.policy]
m = "q4-linear2"
v = "q8-dt"
eigen = "q4"
"#,
        )
        .unwrap();
        assert_eq!(cfg.quant_policy.len(), 3);
        let policy = cfg.codec_policy();
        let fb = CodecSpec::plain(32, Mapping::Dt);
        assert_eq!(policy.resolve(BufferRole::Momentum, fb).name(), "q4-linear2");
        assert_eq!(policy.resolve(BufferRole::SecondMoment, fb).name(), "q8-dt");
        // eigen shorthand takes the second-order default mapping (linear2)
        assert_eq!(policy.resolve(BufferRole::LeftSide, fb).name(), "q4-linear2");
        // no policy → empty entries, knobs unchanged
        assert!(RunConfig::default().quant_policy.is_empty());
        assert!(RunConfig::from_toml_str("").unwrap().codec_policy().is_empty());
    }

    #[test]
    fn quant_policy_rejects_bad_entries() {
        let err = RunConfig::from_toml_str("[quant.policy]\nw = \"q4\"").unwrap_err().to_string();
        assert!(err.contains("quant.policy.w"), "{err}");
        let err = RunConfig::from_toml_str("[quant.policy]\nm = \"q9\"").unwrap_err().to_string();
        assert!(err.contains("valid codecs"), "{err}");
        assert!(RunConfig::from_toml_str("[quant.policy]\nm = 4").is_err());
        // second-order roles must resolve to kernel-compatible bits...
        let err = RunConfig::from_toml_str("[quant.policy]\neigen = \"q8\"")
            .unwrap_err()
            .to_string();
        assert!(err.contains("second-order"), "{err}");
        // ...and never to stochastic rounding
        let err = RunConfig::from_toml_str("[quant.policy]\nleft = \"q4-sr\"")
            .unwrap_err()
            .to_string();
        assert!(err.contains("stochastic"), "{err}");
        // but both are fine when no second-order optimizer runs
        let off = "[shampoo]\nenabled = false\n[quant.policy]\neigen = \"q8\"";
        assert!(RunConfig::from_toml_str(off).is_ok());
        // a policy covering both sides makes the quant.bits knob moot: this
        // run stores every side through q4 even though the knob says 8
        let covered = "[quant]\nbits = 8\n[quant.policy]\neigen = \"q4\"";
        assert!(RunConfig::from_toml_str(covered).is_ok());
        // ...but an uncovered side still fails on the knob's fallback spec
        let uncovered = "[quant]\nbits = 8\n[quant.policy]\nleft = \"q4\"";
        let err = RunConfig::from_toml_str(uncovered).unwrap_err().to_string();
        assert!(err.contains("right"), "{err}");
        // stochastic first-order entries are legal
        let cfg = RunConfig::from_toml_str("[quant.policy]\nm = \"q4-dt-sr\"").unwrap();
        let fb = CodecSpec::plain(32, Mapping::Dt);
        assert!(cfg.codec_policy().resolve(BufferRole::Momentum, fb).stochastic);
    }

    #[test]
    fn shard_keys_parse() {
        let cfg = RunConfig::from_toml_str("[shard]\ncount = 4").unwrap();
        assert_eq!(cfg.second.shards, 4);
        // clamped to >= 1, default 1 (no shard engine)
        let cfg = RunConfig::from_toml_str("[shard]\ncount = 0").unwrap();
        assert_eq!(cfg.second.shards, 1);
        assert_eq!(RunConfig::default().second.shards, 1);
    }

    #[test]
    fn pipeline_adaptive_parses() {
        let cfg = RunConfig::from_toml_str(
            "[shampoo]\npipeline = true\npipeline_adaptive = true",
        )
        .unwrap();
        assert!(cfg.second.pipeline_adaptive);
        assert!(!RunConfig::default().second.pipeline_adaptive);
    }

    #[test]
    fn backend_selection_parses() {
        let cfg = RunConfig::from_toml_str("[run]\nbackend = \"host\"").unwrap();
        assert_eq!(cfg.backend, "host");
        assert_eq!(RunConfig::default().backend, "auto");
    }

    #[test]
    fn disabled_shampoo() {
        let cfg = RunConfig::from_toml_str("[shampoo]\nenabled = false").unwrap();
        assert_eq!(cfg.second.kind, SecondOrderKind::None);
    }

    #[test]
    fn bad_optimizer_rejected() {
        assert!(RunConfig::from_toml_str("[optimizer]\nkind = \"zzz\"").is_err());
    }

    #[test]
    fn cosine_schedule_shape() {
        let mut cfg = RunConfig::default();
        cfg.steps = 100;
        cfg.schedule = Schedule::Cosine { warmup: 10 };
        assert!(cfg.lr_at(0) < 0.2);
        assert!((cfg.lr_at(10) - 1.0).abs() < 0.01);
        assert!(cfg.lr_at(99) < 0.01);
    }

    #[test]
    fn multistep_decays() {
        let mut cfg = RunConfig::default();
        cfg.steps = 100;
        cfg.schedule = Schedule::MultiStep { warmup: 0, decay_every_frac: 0.3, gamma: 0.1 };
        assert!((cfg.lr_at(1) - 1.0).abs() < 1e-6);
        assert!((cfg.lr_at(35) - 0.1).abs() < 1e-6);
        assert!((cfg.lr_at(65) - 0.01).abs() < 1e-6);
    }

    #[test]
    fn alpha_per_kind() {
        assert_eq!(SecondOrderKind::Shampoo.alpha(), 4);
        assert_eq!(SecondOrderKind::AdaBk.alpha(), 2);
        assert_eq!(SecondOrderKind::KFac.alpha(), 1);
    }
}
