//! Dense row-major f32 matrices with the operations the error-analysis
//! harness and the coordinator's host-side math need. Deliberately simple
//! and allocation-explicit; the blocked matmul is the only tuned routine
//! (it is on the Table-1 bench path at order 1200).

use crate::util::rng::Rng;

/// Dense row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major payload, `rows × cols` long.
    pub data: Vec<f32>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix over an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    /// Identity of order n.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Diagonal matrix from a vector.
    pub fn diag(d: &[f32]) -> Mat {
        let mut m = Mat::zeros(d.len(), d.len());
        for (i, &x) in d.iter().enumerate() {
            m[(i, i)] = x;
        }
        m
    }

    /// Standard-normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        Mat::from_vec(rows, cols, rng.normal_vec(rows * cols))
    }

    /// rows == cols.
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Copy of the main diagonal.
    pub fn diagonal(&self) -> Vec<f32> {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).collect()
    }

    /// Borrow row i.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row i.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Aᵀ.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// s·A.
    pub fn scale(&self, s: f32) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.data.iter().map(|x| x * s).collect())
    }

    /// A + B.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        )
    }

    /// A − B.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        )
    }

    /// self + s·I
    pub fn add_scaled_eye(&self, s: f32) -> Mat {
        assert!(self.is_square());
        let mut m = self.clone();
        for i in 0..self.rows {
            m[(i, i)] += s;
        }
        m
    }

    /// ‖A‖_F.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Frobenius inner product ⟨A,B⟩.
    pub fn inner(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum()
    }

    /// Largest |entry|.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Blocked matmul: C = A·B. f64 accumulation over the k-panel keeps
    /// order-1200 products accurate enough for NRE measurements.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(
            self.cols, b.rows,
            "matmul dims {}x{} · {}x{}",
            self.rows, self.cols, b.rows, b.cols
        );
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut c = Mat::zeros(m, n);
        // i-k-j loop order: streams B rows and C rows sequentially.
        const KB: usize = 64;
        for i in 0..m {
            let crow = &mut c.data[i * n..(i + 1) * n];
            for k0 in (0..k).step_by(KB) {
                let kend = (k0 + KB).min(k);
                for kk in k0..kend {
                    let a = self.data[i * k + kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += a * brow[j];
                    }
                }
            }
        }
        c
    }

    /// C = Aᵀ·A (Gram), exploiting symmetry.
    pub fn gram_t(&self) -> Mat {
        let (m, n) = (self.rows, self.cols);
        let mut c = Mat::zeros(n, n);
        for i in 0..m {
            let row = self.row(i);
            for a in 0..n {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                let dst = &mut c.data[a * n..(a + 1) * n];
                for bcol in a..n {
                    dst[bcol] += ra * row[bcol];
                }
            }
        }
        for a in 0..n {
            for bcol in 0..a {
                c.data[a * n + bcol] = c.data[bcol * n + a];
            }
        }
        c
    }

    /// C = A·Aᵀ (Gram on rows).
    pub fn gram(&self) -> Mat {
        self.transpose().gram_t()
    }

    /// V·diag(d)·Vᵀ — preconditioner reconstruction.
    pub fn sandwich(v: &Mat, d: &[f32]) -> Mat {
        assert_eq!(v.cols, d.len());
        let mut vd = v.clone();
        for i in 0..v.rows {
            let row = vd.row_mut(i);
            for j in 0..d.len() {
                row[j] *= d[j];
            }
        }
        vd.matmul(&v.transpose())
    }

    /// A·x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| {
                self.row(i)
                    .iter()
                    .zip(x)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum::<f64>() as f32
            })
            .collect()
    }

    /// Symmetrize in place: (A + Aᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        let n = self.rows;
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity_property() {
        prop::check("A·I = A", 20, |rng| {
            let m = 1 + rng.below(20);
            let n = 1 + rng.below(20);
            let a = Mat::randn(m, n, rng);
            let c = a.matmul(&Mat::eye(n));
            prop::assert_close(&c.data, &a.data, 1e-6, 1e-6)
        });
    }

    #[test]
    fn matmul_associativity_property() {
        prop::check("(AB)C = A(BC)", 10, |rng| {
            let (m, k, l, n) =
                (1 + rng.below(12), 1 + rng.below(12), 1 + rng.below(12), 1 + rng.below(12));
            let a = Mat::randn(m, k, rng);
            let b = Mat::randn(k, l, rng);
            let c = Mat::randn(l, n, rng);
            let lhs = a.matmul(&b).matmul(&c);
            let rhs = a.matmul(&b.matmul(&c));
            prop::assert_close(&lhs.data, &rhs.data, 1e-3, 1e-3)
        });
    }

    #[test]
    fn gram_matches_matmul() {
        prop::check("AᵀA = gram_t(A)", 15, |rng| {
            let a = Mat::randn(1 + rng.below(15), 1 + rng.below(15), rng);
            let want = a.transpose().matmul(&a);
            prop::assert_close(&a.gram_t().data, &want.data, 1e-4, 1e-4)
        });
    }

    #[test]
    fn sandwich_matches_explicit() {
        prop::check("VDVᵀ", 10, |rng| {
            let n = 1 + rng.below(12);
            let v = Mat::randn(n, n, rng);
            let d: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let want = v.matmul(&Mat::diag(&d)).matmul(&v.transpose());
            prop::assert_close(&Mat::sandwich(&v, &d).data, &want.data, 1e-4, 1e-3)
        });
    }

    #[test]
    fn transpose_involution() {
        prop::check("(Aᵀ)ᵀ = A", 10, |rng| {
            let a = Mat::randn(1 + rng.below(10), 1 + rng.below(10), rng);
            prop::assert_close(&a.transpose().transpose().data, &a.data, 0.0, 0.0)
        });
    }

    #[test]
    fn frobenius_and_inner() {
        let a = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.frobenius() - 5.0).abs() < 1e-12);
        assert!((a.inner(&a) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn matvec_matches_matmul() {
        prop::check("Ax", 10, |rng| {
            let (m, n) = (1 + rng.below(12), 1 + rng.below(12));
            let a = Mat::randn(m, n, rng);
            let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let xm = Mat::from_vec(n, 1, x.clone());
            prop::assert_close(&a.matvec(&x), &a.matmul(&xm).data, 1e-4, 1e-4)
        });
    }
}
