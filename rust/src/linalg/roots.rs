//! Matrix-root toolbox: the Rust mirrors of the L2 algorithms (power
//! iteration, Schur–Newton inverse p-th root, Björck orthonormalization).
//! Used by the error-analysis harness (where exactness matters more than
//! speed) and cross-checked against the eigendecomposition reference.

use super::dense::Mat;
use super::eig::eigh;

/// λ_max estimate by power iteration (deterministic start, like L2).
pub fn power_iteration(a: &Mat, iters: usize) -> f32 {
    assert!(a.is_square());
    let n = a.rows;
    let mut v = vec![1.0f32 / (n as f32).sqrt(); n];
    for _ in 0..iters {
        let w = a.matvec(&v);
        let norm = w.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt() as f32;
        if norm < 1e-30 {
            return 0.0;
        }
        for (vi, wi) in v.iter_mut().zip(&w) {
            *vi = wi / norm;
        }
    }
    let av = a.matvec(&v);
    v.iter().zip(&av).map(|(&x, &y)| (x as f64) * (y as f64)).sum::<f64>() as f32
}

/// A^{-1/p} by the coupled Newton (Schur–Newton) iteration with
/// best-iterate selection (same guard as L2: quantized inputs can be
/// indefinite and the iteration diverges on negative eigendirections).
pub fn schur_newton_invroot(a: &Mat, p: u32, iters: usize) -> Mat {
    assert!(a.is_square());
    let n = a.rows;
    let lam_max = power_iteration(a, 20).max(1e-30);
    let z = 1.0 / lam_max;
    let eye = Mat::eye(n);
    let mut m = a.scale(z);
    let mut x = Mat::eye(n).scale(z.powf(1.0 / p as f32));
    let mut best_x = x.clone();
    let mut best_err = m.sub(&eye).max_abs();
    for _ in 0..iters {
        let t = eye.scale((p + 1) as f32).sub(&m).scale(1.0 / p as f32);
        let x_new = x.matmul(&t);
        let tp = match p {
            2 => t.matmul(&t),
            4 => {
                let t2 = t.matmul(&t);
                t2.matmul(&t2)
            }
            _ => {
                let mut acc = t.clone();
                for _ in 0..p - 1 {
                    acc = acc.matmul(&t);
                }
                acc
            }
        };
        let m_new = tp.matmul(&m);
        let err = m_new.sub(&eye).max_abs();
        if !err.is_finite() {
            break;
        }
        x = x_new;
        m = m_new;
        if err < best_err {
            best_err = err;
            best_x = x.clone();
        }
    }
    best_x.symmetrize();
    best_x
}

/// Exact A^{-1/p} via eigendecomposition (the measurement reference).
pub fn invroot_eigh(a: &Mat, p: f64, floor: f64) -> Mat {
    eigh(a).matrix_power(-1.0 / p, floor)
}

/// One Björck orthonormalization step: V ← 1.5·V − 0.5·V·VᵀV (paper eq. 2).
pub fn bjorck_step(v: &Mat) -> Mat {
    let g = v.gram_t(); // VᵀV
    v.scale(1.5).sub(&v.matmul(&g).scale(0.5))
}

/// `iters` Björck orthogonality-rectification steps (paper Algorithm 2).
pub fn bjorck(v: &Mat, iters: usize) -> Mat {
    let mut out = v.clone();
    for _ in 0..iters {
        out = bjorck_step(&out);
    }
    out
}

/// Orthogonality deviation ‖VᵀV − I‖_F (rectification diagnostics).
pub fn orthogonality_error(v: &Mat) -> f64 {
    let g = v.gram_t();
    let eye = Mat::eye(v.cols);
    g.sub(&eye).frobenius()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::random_orthogonal;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn pd_with_spectrum(vals: &[f32], rng: &mut Rng) -> (Mat, Mat) {
        let q = random_orthogonal(vals.len(), rng);
        (Mat::sandwich(&q, vals), q)
    }

    #[test]
    fn power_iteration_finds_lam_max() {
        prop::check("λmax", 10, |rng| {
            let n = 4 + rng.below(24);
            let vals: Vec<f32> = (0..n).map(|i| 1.0 + i as f32).collect();
            let (a, _) = pd_with_spectrum(&vals, rng);
            let est = power_iteration(&a, 100);
            let want = n as f32;
            if (est - want).abs() / want > 5e-3 {
                return Err(format!("{est} vs {want}"));
            }
            Ok(())
        });
    }

    #[test]
    fn schur_newton_matches_eigh() {
        prop::check("A^{-1/4}", 6, |rng| {
            let n = 6 + rng.below(20);
            let vals: Vec<f32> = (0..n).map(|i| 0.5 + 0.37 * i as f32).collect();
            let (a, _) = pd_with_spectrum(&vals, rng);
            let sn = schur_newton_invroot(&a, 4, 30);
            let ex = invroot_eigh(&a, 4.0, 1e-12);
            let rel = sn.sub(&ex).frobenius() / ex.frobenius();
            if rel > 1e-2 {
                return Err(format!("rel err {rel}"));
            }
            Ok(())
        });
    }

    #[test]
    fn schur_newton_survives_indefinite_input() {
        // quantization can push small eigenvalues negative; the iteration
        // must return something finite (best-iterate guard)
        let mut rng = Rng::new(4);
        let vals: Vec<f32> = (0..16).map(|i| if i == 0 { -1e-3 } else { 1.0 + i as f32 }).collect();
        let (a, _) = pd_with_spectrum(&vals, &mut rng);
        let x = schur_newton_invroot(&a, 4, 25);
        assert!(x.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn bjorck_rectifies() {
        prop::check("björck improves orthogonality", 10, |rng| {
            let n = 8 + rng.below(24);
            let q = random_orthogonal(n, rng);
            let noise = Mat::randn(n, n, rng).scale(0.02);
            let v = q.add(&noise);
            let e0 = orthogonality_error(&v);
            let e1 = orthogonality_error(&bjorck(&v, 1));
            let e2 = orthogonality_error(&bjorck(&v, 2));
            if !(e1 < 0.6 * e0 && e2 <= e1 + 1e-9) {
                return Err(format!("e0={e0} e1={e1} e2={e2}"));
            }
            Ok(())
        });
    }

    #[test]
    fn invroot_eigh_identity() {
        let a = Mat::eye(8).scale(16.0);
        let x = invroot_eigh(&a, 4.0, 1e-12);
        // 16^{-1/4} = 0.5
        prop::assert_close(&x.data, &Mat::eye(8).scale(0.5).data, 1e-5, 1e-5).unwrap();
    }
}
