//! Householder QR (exact reference for the CGS2 orthogonalizer in L2) and
//! random orthogonal matrix generation for the synthetic spectra of the
//! paper's error analyses (A₂ in Table 1, spectrum-matched A₁).

use super::dense::Mat;
use crate::util::rng::Rng;

/// Householder QR: A = Q·R with Q orthogonal (m×m) and R upper triangular.
/// Returns (Q, R). For the square matrices used here m == n.
pub fn householder_qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    let mut r: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut q = vec![0.0f64; m * m];
    for i in 0..m {
        q[i * m + i] = 1.0;
    }
    for k in 0..n.min(m.saturating_sub(1)) {
        // Householder vector for column k
        let mut norm = 0.0;
        for i in k..m {
            norm += r[i * n + k] * r[i * n + k];
        }
        let norm = norm.sqrt();
        if norm < 1e-300 {
            continue;
        }
        let alpha = if r[k * n + k] > 0.0 { -norm } else { norm };
        let mut v = vec![0.0f64; m];
        v[k] = r[k * n + k] - alpha;
        for i in (k + 1)..m {
            v[i] = r[i * n + k];
        }
        let vnorm2: f64 = v[k..].iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            continue;
        }
        // R <- (I - 2vvᵀ/|v|²) R
        for j in k..n {
            let dot: f64 = (k..m).map(|i| v[i] * r[i * n + j]).sum();
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                r[i * n + j] -= f * v[i];
            }
        }
        // Q <- Q (I - 2vvᵀ/|v|²)
        for i in 0..m {
            let dot: f64 = (k..m).map(|j| q[i * m + j] * v[j]).sum();
            let f = 2.0 * dot / vnorm2;
            for j in k..m {
                q[i * m + j] -= f * v[j];
            }
        }
    }
    // zero the numerically-subdiagonal part of R
    for i in 0..m {
        for j in 0..n.min(i) {
            r[i * n + j] = 0.0;
        }
    }
    (
        Mat::from_vec(m, m, q.iter().map(|&x| x as f32).collect()),
        Mat::from_vec(m, n, r.iter().map(|&x| x as f32).collect()),
    )
}

/// QR orthogonalization via classical Gram–Schmidt with reorthogonalization
/// (CGS2, "twice is enough" [Björck]) — the Rust mirror of the L2
/// `orthogonalize_cgs2` used inside subspace iteration. Columns whose
/// residual vanishes (exact rank deficiency, e.g. padded blocks) are left
/// near-zero rather than replaced: downstream they are always weighted by
/// the matching ≈0 eigenvalue.
pub fn orthogonalize_cgs2(x: &Mat) -> Mat {
    let (n, m) = (x.rows, x.cols);
    let mut q = Mat::zeros(n, m);
    let mut v = vec![0.0f64; n];
    for j in 0..m {
        for (i, vi) in v.iter_mut().enumerate() {
            *vi = x[(i, j)] as f64;
        }
        for _pass in 0..2 {
            for k in 0..j {
                let dot: f64 = (0..n).map(|i| q[(i, k)] as f64 * v[i]).sum();
                for (i, vi) in v.iter_mut().enumerate() {
                    *vi -= dot * q[(i, k)] as f64;
                }
            }
        }
        let norm = v.iter().map(|a| a * a).sum::<f64>().sqrt().max(1e-30);
        for (i, &vi) in v.iter().enumerate() {
            q[(i, j)] = (vi / norm) as f32;
        }
    }
    q
}

/// Random orthogonal matrix: QR of a Gaussian matrix with sign-fixed R
/// diagonal (Haar-ish; exact Haar is not needed for the error analyses).
pub fn random_orthogonal(n: usize, rng: &mut Rng) -> Mat {
    let g = Mat::randn(n, n, rng);
    let (mut q, r) = householder_qr(&g);
    // fix signs so the distribution is not biased by the QR convention
    for j in 0..n {
        if r[(j, j)] < 0.0 {
            for i in 0..n {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn qr_reconstructs() {
        prop::check("QR = A", 15, |rng| {
            let n = 1 + rng.below(24);
            let a = Mat::randn(n, n, rng);
            let (q, r) = householder_qr(&a);
            prop::assert_close(&q.matmul(&r).data, &a.data, 1e-4, 1e-3)
        });
    }

    #[test]
    fn q_is_orthogonal() {
        prop::check("QᵀQ = I", 15, |rng| {
            let n = 1 + rng.below(24);
            let a = Mat::randn(n, n, rng);
            let (q, _) = householder_qr(&a);
            prop::assert_close(
                &q.transpose().matmul(&q).data,
                &Mat::eye(n).data,
                1e-4,
                1e-4,
            )
        });
    }

    #[test]
    fn r_is_upper_triangular() {
        prop::check("R upper", 10, |rng| {
            let n = 2 + rng.below(16);
            let a = Mat::randn(n, n, rng);
            let (_, r) = householder_qr(&a);
            for i in 0..n {
                for j in 0..i {
                    if r[(i, j)].abs() > 1e-5 {
                        return Err(format!("R[{i},{j}] = {}", r[(i, j)]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn random_orthogonal_is_orthogonal() {
        prop::check("rand orth", 10, |rng| {
            let n = 2 + rng.below(32);
            let q = random_orthogonal(n, rng);
            prop::assert_close(
                &q.gram_t().data,
                &Mat::eye(n).data,
                1e-4,
                1e-4,
            )
        });
    }
}
