//! Symmetric eigendecomposition — the exact reference for the paper's
//! error analyses (Tables 1/5/6/7, Figures 2/3/6) at order 1200.
//!
//! Two solvers, cross-checked in tests:
//!  * `eigh_jacobi` — cyclic Jacobi; simple, very accurate, O(n³ · sweeps);
//!  * `eigh`        — Householder tridiagonalization + implicit-shift QL
//!    (tred2/tqli), ~4/3·n³; the fast path used by the benches.
//!
//! Both return eigenvalues ascending with matching eigenvector columns.

use super::dense::Mat;

/// Eigendecomposition result: A = V · diag(vals) · Vᵀ.
pub struct Eigh {
    /// Eigenvalues, ascending.
    pub vals: Vec<f32>,
    /// Matching eigenvectors as columns.
    pub vecs: Mat,
}

impl Eigh {
    /// Reconstruct f(A) = V·diag(f(λ))·Vᵀ.
    pub fn apply_fn(&self, f: impl Fn(f64) -> f64) -> Mat {
        let d: Vec<f32> = self.vals.iter().map(|&x| f(x as f64) as f32).collect();
        Mat::sandwich(&self.vecs, &d)
    }

    /// A^s with eigenvalue floor (negative/zero eigenvalues clamped).
    pub fn matrix_power(&self, s: f64, floor: f64) -> Mat {
        self.apply_fn(|x| x.max(floor).powf(s))
    }
}

/// Cyclic Jacobi eigenvalue algorithm (reference implementation).
pub fn eigh_jacobi(a: &Mat, max_sweeps: usize) -> Eigh {
    assert!(a.is_square());
    let n = a.rows;
    // work in f64 for accuracy
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[i * n + j] * m[i * n + j];
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + frob64(&m)) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rotate rows/cols p, q
                for k in 0..n {
                    let akp = m[k * n + p];
                    let akq = m[k * n + q];
                    m[k * n + p] = c * akp - s * akq;
                    m[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[p * n + k];
                    let aqk = m[q * n + k];
                    m[p * n + k] = c * apk - s * aqk;
                    m[q * n + k] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut vals: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    sort_eig(&mut vals, &mut v, n);
    Eigh {
        vals: vals.iter().map(|&x| x as f32).collect(),
        vecs: Mat::from_vec(n, n, v.iter().map(|&x| x as f32).collect()),
    }
}

fn frob64(m: &[f64]) -> f64 {
    m.iter().map(|&x| x * x).sum::<f64>().sqrt()
}

fn sort_eig(vals: &mut [f64], vecs: &mut [f64], n: usize) {
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap());
    let old_vals = vals.to_vec();
    let old_vecs = vecs.to_vec();
    for (new, &old) in idx.iter().enumerate() {
        vals[new] = old_vals[old];
        for r in 0..n {
            vecs[r * n + new] = old_vecs[r * n + old];
        }
    }
}

/// Householder tridiagonalization + implicit-shift QL (tred2/tqli).
/// The fast exact solver for the order-1200 error analyses.
pub fn eigh(a: &Mat) -> Eigh {
    assert!(a.is_square());
    let n = a.rows;
    let mut z: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut d = vec![0.0f64; n];
    let mut e = vec![0.0f64; n];
    tred2(&mut z, &mut d, &mut e, n);
    tqli(&mut d, &mut e, &mut z, n);
    sort_eig(&mut d, &mut z, n);
    Eigh {
        vals: d.iter().map(|&x| x as f32).collect(),
        vecs: Mat::from_vec(n, n, z.iter().map(|&x| x as f32).collect()),
    }
}

/// Householder reduction of a real symmetric matrix to tridiagonal form.
/// (Numerical Recipes tred2, with eigenvector accumulation.)
fn tred2(z: &mut [f64], d: &mut [f64], e: &mut [f64], n: usize) {
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let mut scale = 0.0;
            for k in 0..=l {
                scale += z[i * n + k].abs();
            }
            if scale == 0.0 {
                e[i] = z[i * n + l];
            } else {
                for k in 0..=l {
                    z[i * n + k] /= scale;
                    h += z[i * n + k] * z[i * n + k];
                }
                let mut f = z[i * n + l];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[i * n + l] = f - g;
                f = 0.0;
                for j in 0..=l {
                    z[j * n + i] = z[i * n + j] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[j * n + k] * z[i * n + k];
                    }
                    for k in (j + 1)..=l {
                        g += z[k * n + j] * z[i * n + k];
                    }
                    e[j] = g / h;
                    f += e[j] * z[i * n + j];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z[i * n + j];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        z[j * n + k] -= f * e[k] + g * z[i * n + k];
                    }
                }
            }
        } else {
            e[i] = z[i * n + l];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z[i * n + k] * z[k * n + j];
                }
                for k in 0..i {
                    z[k * n + j] -= g * z[k * n + i];
                }
            }
        }
        d[i] = z[i * n + i];
        z[i * n + i] = 1.0;
        for j in 0..i {
            z[j * n + i] = 0.0;
            z[i * n + j] = 0.0;
        }
    }
}

/// Implicit-shift QL with eigenvector accumulation (Numerical Recipes tqli).
fn tqli(d: &mut [f64], e: &mut [f64], z: &mut [f64], n: usize) {
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter <= 50, "tqli: too many iterations");
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + if g >= 0.0 { r.abs() } else { -r.abs() });
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                for k in 0..n {
                    f = z[k * n + i + 1];
                    z[k * n + i + 1] = s * z[k * n + i] + c * f;
                    z[k * n + i] = c * z[k * n + i] - s * f;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_sym(n: usize, rng: &mut Rng) -> Mat {
        let b = Mat::randn(n, n, rng);
        let mut a = b.add(&b.transpose()).scale(0.5);
        a.symmetrize();
        a
    }

    fn check_decomp(a: &Mat, e: &Eigh, tol: f32) -> Result<(), String> {
        let rec = Mat::sandwich(&e.vecs, &e.vals);
        prop::assert_close(&rec.data, &a.data, tol, tol)?;
        // orthogonality
        let vtv = e.vecs.transpose().matmul(&e.vecs);
        let eye = Mat::eye(a.rows);
        prop::assert_close(&vtv.data, &eye.data, tol, tol)?;
        // ascending
        for w in e.vals.windows(2) {
            if w[0] > w[1] + 1e-6 {
                return Err(format!("not ascending: {} > {}", w[0], w[1]));
            }
        }
        Ok(())
    }

    #[test]
    fn jacobi_reconstructs() {
        prop::check("jacobi: VΛVᵀ = A", 10, |rng| {
            let n = 2 + rng.below(20);
            let a = random_sym(n, rng);
            check_decomp(&a, &eigh_jacobi(&a, 30), 2e-4)
        });
    }

    #[test]
    fn tqli_reconstructs() {
        prop::check("tred2/tqli: VΛVᵀ = A", 10, |rng| {
            let n = 2 + rng.below(40);
            let a = random_sym(n, rng);
            check_decomp(&a, &eigh(&a), 5e-4)
        });
    }

    #[test]
    fn solvers_agree_on_eigenvalues() {
        prop::check("jacobi ≍ tqli", 8, |rng| {
            let n = 2 + rng.below(24);
            let a = random_sym(n, rng);
            let e1 = eigh_jacobi(&a, 30);
            let e2 = eigh(&a);
            prop::assert_close(&e1.vals, &e2.vals, 1e-3, 1e-3)
        });
    }

    #[test]
    fn known_spectrum() {
        // diag(1, 2, 3) rotated by a known orthogonal matrix
        let mut rng = Rng::new(77);
        let n = 3;
        let g = Mat::randn(n, n, &mut rng);
        let q = super::super::qr::householder_qr(&g).0;
        let a = Mat::sandwich(&q, &[1.0, 2.0, 3.0]);
        let e = eigh(&a);
        prop::assert_close(&e.vals, &[1.0, 2.0, 3.0], 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn matrix_power_inverse_root() {
        let mut rng = Rng::new(5);
        let n = 24;
        let b = Mat::randn(n, n + 4, &mut rng);
        let a = b.gram().scale(1.0 / n as f32).add_scaled_eye(0.1);
        let e = eigh(&a);
        let inv4 = e.matrix_power(-0.25, 1e-12);
        // (A^{-1/4})⁴ · A ≈ I
        let p2 = inv4.matmul(&inv4);
        let p4 = p2.matmul(&p2);
        let prod = p4.matmul(&a);
        prop::assert_close(&prod.data, &Mat::eye(n).data, 2e-2, 2e-2).unwrap();
    }

    #[test]
    fn handles_degenerate_spectrum() {
        // repeated eigenvalues (the paper's synthetic A₂ has only two)
        let mut rng = Rng::new(9);
        let n = 16;
        let g = Mat::randn(n, n, &mut rng);
        let q = super::super::qr::householder_qr(&g).0;
        let mut d = vec![1.0f32; n];
        for x in d.iter_mut().take(n / 2) {
            *x = 1000.0;
        }
        let a = Mat::sandwich(&q, &d);
        let e = eigh(&a);
        let rec = Mat::sandwich(&e.vecs, &e.vals);
        prop::assert_close(&rec.data, &a.data, 0.5, 1e-3).unwrap();
    }
}
