//! Dense linear algebra substrate (in-tree; offline build).
//!
//! Exact references for the paper's error analyses at order 1200
//! (Tables 1/5/6/7, Figures 2/3/6) and host-side math for the coordinator.

/// Dense row-major f32 matrices.
pub mod dense;
/// Symmetric eigendecomposition (Jacobi + tred2/tqli).
pub mod eig;
/// QR / CGS2 orthogonalization.
pub mod qr;
/// Matrix roots: Schur–Newton inverse p-th roots, Björck, power iteration.
pub mod roots;

pub use dense::Mat;
pub use eig::{eigh, eigh_jacobi, Eigh};
pub use qr::{householder_qr, orthogonalize_cgs2, random_orthogonal};
pub use roots::{
    bjorck, bjorck_step, invroot_eigh, orthogonality_error, power_iteration,
    schur_newton_invroot,
};
