//! Hermetic stub of the `xla` crate (LaurentMazare/xla-rs) covering exactly
//! the API surface `shampoo4`'s PJRT registry uses.
//!
//! The real crate links the native XLA/PJRT C++ library, which cannot be
//! built offline. This stub keeps `--features pjrt` compiling everywhere:
//! every runtime entry point (`PjRtClient::cpu`,
//! `HloModuleProto::from_text_file`) returns [`Error::Unavailable`], so the
//! registry fails loudly at construction instead of crashing mid-run. Swap
//! the `xla` dependency in `rust/Cargo.toml` for the real crate to execute
//! AOT artifacts.

use std::borrow::Borrow;
use std::path::Path;

/// Error type standing in for xla-rs's. Implements `std::error::Error` so it
/// threads through `anyhow` exactly like the real one.
#[derive(Debug, Clone)]
pub enum Error {
    /// The stub was invoked at runtime: no native XLA library is linked.
    Unavailable(&'static str),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: built against the in-tree xla stub; link the real \
                 xla-rs crate to use the PJRT backend"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Marker for element types that cross the literal boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    Pred,
    S32,
    S64,
    U8,
    F32,
    F64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    F32,
    F64,
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal { _private: () }
    }

    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable("Literal::reshape"))
    }

    pub fn create_from_shape(_ty: PrimitiveType, _dims: &[usize]) -> Literal {
        Literal { _private: () }
    }

    pub fn copy_raw_from(&mut self, _src: &[u8]) -> Result<()> {
        Err(Error::Unavailable("Literal::copy_raw_from"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::Unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}
